"""CLI: ``python -m repro.analysis [--lint] [--audit] [--sanitize-smoke]``.

With no mode flags all three run. Positional paths switch to
lint-only mode over exactly those files/directories with EVERY rule
active (that is how the seeded-violation fixtures are checked:
``python -m repro.analysis tests/fixtures/lint/bad_mutable_default.py``
must exit nonzero).

Violations are compared against ``analysis/baseline.json``: a finding
whose ``path::rule`` count exceeds the baselined count fails the run,
so pre-existing accepted findings never block a merge while any NEW
one does. ``--write-baseline`` regenerates the file from the current
tree (review the diff before committing it).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

from repro.analysis.lint import lint_paths, lint_repo

BASELINE = Path(__file__).resolve().parent / "baseline.json"


def _load_baseline(path: Path) -> dict:
    if not path.exists():
        return {"lint": {}, "audit": {}}
    return json.loads(path.read_text())


def _diff_vs_baseline(kind: str, keys, baseline: dict) -> list:
    """Returns the findings in excess of the baselined counts."""
    counts = Counter(keys)
    allowed = Counter(baseline.get(kind, {}))
    fresh = []
    for key, n in sorted(counts.items()):
        if n > allowed.get(key, 0):
            fresh.append((key, n, allowed.get(key, 0)))
    return fresh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("paths", nargs="*",
                    help="lint exactly these files/dirs (all rules)")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--audit", action="store_true")
    ap.add_argument("--trace-all", action="store_true",
                    help="audit: trace every registry combo instead of "
                         "one representative per shape class")
    ap.add_argument("--sanitize-smoke", action="store_true")
    ap.add_argument("--baseline", type=Path, default=BASELINE)
    ap.add_argument("--write-baseline", action="store_true")
    args = ap.parse_args(argv)

    if args.paths:
        violations = lint_paths(args.paths)
        for v in violations:
            print(v)
        print(f"# lint: {len(violations)} violation(s) in "
              f"{len(args.paths)} path(s)")
        return 1 if violations else 0

    run_all = not (args.lint or args.audit or args.sanitize_smoke)
    baseline = _load_baseline(args.baseline)
    failed = False
    new_baseline = {"lint": {}, "audit": {}}

    if args.lint or run_all:
        violations = lint_repo()
        new_baseline["lint"] = dict(
            Counter(v.key for v in violations)
        )
        fresh = _diff_vs_baseline(
            "lint", (v.key for v in violations), baseline
        )
        for v in violations:
            print(v)
        if fresh:
            failed = True
            for key, n, allowed in fresh:
                print(f"# NEW lint violation {key}: {n} > baseline "
                      f"{allowed}", file=sys.stderr)
        print(f"# lint: {len(violations)} finding(s), "
              f"{len(fresh)} beyond baseline")

    if args.audit or run_all:
        from repro.analysis.audit import audit_all

        violations = audit_all(trace_all=args.trace_all)
        new_baseline["audit"] = dict(
            Counter(f"{v.combo}::{v.check}" for v in violations)
        )
        fresh = _diff_vs_baseline(
            "audit",
            (f"{v.combo}::{v.check}" for v in violations), baseline,
        )
        for v in violations:
            print(v)
        if fresh:
            failed = True
            for key, n, allowed in fresh:
                print(f"# NEW audit violation {key}: {n} > baseline "
                      f"{allowed}", file=sys.stderr)
        print(f"# audit: {len(violations)} finding(s), "
              f"{len(fresh)} beyond baseline")

    if args.sanitize_smoke or run_all:
        from repro.analysis.sanitize import sanitize_smoke

        results = sanitize_smoke()
        dirty = [(n, m) for n, m in results if m is not None]
        for name, msg in results:
            print(f"# sanitize {name}: {'CLEAN' if msg is None else msg}")
        if dirty:
            failed = True
            print(f"# sanitize: {len(dirty)} case(s) raised checkify "
                  "errors", file=sys.stderr)
        else:
            print(f"# sanitize: {len(results)} case(s) clean")

    if args.write_baseline:
        args.baseline.write_text(
            json.dumps(new_baseline, indent=2, sort_keys=True) + "\n"
        )
        print(f"# baseline written to {args.baseline}")
        return 0
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
