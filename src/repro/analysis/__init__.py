"""Static-analysis layer: jaxpr invariant auditor + repo-specific lint.

The repo's standing invariants (pallas-vs-reference bit-parity,
float32 discipline in every scan carry, one compilation per
(policy, backend) shape class) are enforced dynamically by tests --
which can silently stop running (PR 5 found a whole module skipped for
years behind a vestigial importorskip). This package enforces them
*statically*, before anything executes:

  * ``analysis.audit``    -- traces every registered
    (policy x backend x scenario) combination with ``jax.make_jaxpr``
    and checks dtype discipline, scan-carry stability, the absence of
    host callbacks in jitted paths, and that each (policy, backend)
    presents exactly one abstract signature per shape class across the
    scenario registry (the retrace audit).
  * ``analysis.sanitize`` -- lifts the simulators through
    ``jax.experimental.checkify`` (NaN / div-by-zero / OOB index) and
    runs a CI smoke battery.
  * ``analysis.lint``     -- stdlib-``ast`` lint with repo-specific
    rules (host casts on traced values, Python ``for`` over jnp arrays,
    direct ``pltpu`` imports bypassing ``kernels/compat.py``, ``np.``
    inside scan bodies, mutable default args, unused imports).

CLI: ``python -m repro.analysis [--lint] [--audit] [--sanitize-smoke]``
exits nonzero on any violation not recorded in ``baseline.json``.
See DESIGN.md §Static analysis.
"""
from repro.analysis.audit import (
    AuditViolation,
    audit_all,
    audit_combo,
    iter_combos,
    retrace_audit,
)
from repro.analysis.lint import LintViolation, lint_paths, lint_repo
from repro.analysis.sanitize import checkified_simulate_fleet, sanitize_smoke

__all__ = [
    "AuditViolation",
    "audit_all",
    "audit_combo",
    "iter_combos",
    "retrace_audit",
    "LintViolation",
    "lint_paths",
    "lint_repo",
    "checkified_simulate_fleet",
    "sanitize_smoke",
]
