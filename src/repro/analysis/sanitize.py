"""Checkify sanitizer harness for the simulators.

``jax.experimental.checkify`` instruments a traced program with
functional error checks -- NaN production, division by zero, out-of-
bounds gather/scatter -- that jit compiles away into a threaded error
value instead of silently producing garbage. The repo had zero checkify
coverage before this module; the carbon ledger (emissions accounting)
is exactly the kind of number a NaN corrupts silently at fleet scale.

``checkified_simulate_fleet`` lifts a whole fleet simulation;
``sanitize_smoke`` is the CI battery (one case per simulator entry
point, including the chunked-fill ``while_loop`` path), run by
``python -m repro.analysis --sanitize-smoke``.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax
from jax.experimental import checkify

# NaN + div-by-zero + OOB-index: everything that can corrupt the carbon
# ledger without crashing. user_checks stays out of the default set so
# future explicit checkify.check() calls can be opted in separately.
DEFAULT_CHECKS = checkify.float_checks | checkify.index_checks

# The fleet simulators vmap the per-instance program, and checkify's
# OOB rule cannot instrument a *batched* scatter (jax<=0.4.37 raises
# IndexError from the error rule itself on any scatter carrying
# operand_batching_dims), nor discharge through a vmapped while_loop.
# Fleet lifts therefore run NaN + div-by-zero only; OOB coverage for
# the identical per-instance program comes from the single-instance
# lanes in `sanitize_smoke`, which carry the full DEFAULT_CHECKS.
FLEET_CHECKS = checkify.float_checks

SMOKE_T = 24
SMOKE_M, SMOKE_N = 4, 3
SMOKE_PER_KIND = 2


def checkified_simulate_fleet(
    policy: Callable,
    fleet,
    T: int,
    key,
    forecaster: Callable | None = None,
    record="summary",
    errors=FLEET_CHECKS,
):
    """Runs ``simulate_fleet`` under checkify and returns
    ``(error, result)``. ``error.get()`` is None on a clean run; call
    ``error.throw()`` to raise instead. The checkified program is
    jitted, so the checks compile into the fleet scan itself rather
    than running in op-by-op eager mode."""
    from repro.core.simulator import simulate_fleet

    def run(k):
        return simulate_fleet(
            policy, fleet, T, k, forecaster=forecaster, record=record
        )

    checked = checkify.checkify(run, errors=errors)
    return jax.jit(checked)(key)


def sanitize_smoke(T: int = SMOKE_T) -> List[Tuple[str, str | None]]:
    """One checkified run per simulator entry point at smoke size.
    Returns ``[(case name, error message or None)]``; all-None = clean.

    Fleet lanes run ``FLEET_CHECKS`` (NaN + div-by-zero); the
    single-instance lanes run full ``DEFAULT_CHECKS`` including OOB
    index checks -- see the ``FLEET_CHECKS`` comment for why.

    Cases:
      * ``simulate_fleet`` on the diurnal-slack fleet (the acceptance
        anchor) under the default policy;
      * the same fleet under ``LookaheadDPPPolicy`` + seasonal-naive
        forecaster (forecast carry threading + the deferral math);
      * single-instance ``simulate`` with ``fill_chunk < M`` forcing the
        chunked greedy fill's ``while_loop`` path (checkify must
        discharge the full check set through it);
      * the WAN path: ``NetworkAwareDPPPolicy`` on the congested-uplink
        topology (transfer dynamics incl. the bw=inf-safe drain ratio);
      * fleet sweep with the clairvoyant forecaster + error model (the
        ``jax.random.normal`` corruption path);
      * single-instance ``simulate`` at the paper spec with full checks;
      * the fault layer: the blackout fleet under the staleness guard,
        the flappy-uplink WAN fleet (hard link flap -> the bw-scale
        ``inf * 0`` guard in ``step_links``), and a single-instance
        faulted run with full checks (outage masking, stochastic
        requeue rounding, and the wasted-emissions ledger must all
        stay NaN-free and in-bounds).
    """
    from repro.configs.fleet_scenarios import (
        build_fleet,
        build_network_fleet,
    )
    from repro.core.policies import (
        CarbonIntensityPolicy,
        LookaheadDPPPolicy,
    )
    from repro.core.simulator import simulate, sweep_forecast_errors
    from repro.forecast import (
        ClairvoyantTableForecaster,
        SeasonalNaiveForecaster,
    )
    from repro.configs.fleet_scenarios import with_faults
    from repro.faults import StalenessGuardPolicy
    from repro.network import NetworkAwareDPPPolicy

    key = jax.random.PRNGKey(0)
    fleet = build_fleet(["diurnal-slack"], per_kind=SMOKE_PER_KIND,
                        M=SMOKE_M, N=SMOKE_N, Tc=24, seed=0)
    wan = build_network_fleet(["congested-uplink"],
                              per_kind=SMOKE_PER_KIND, M=SMOKE_M,
                              N=SMOKE_N, Tc=24, seed=0)
    cases = [
        ("fleet/diurnal-slack/ci",
         lambda: checkified_simulate_fleet(
             CarbonIntensityPolicy(), fleet, T, key)),
        ("fleet/diurnal-slack/lookahead-seasonal",
         lambda: checkified_simulate_fleet(
             LookaheadDPPPolicy(H=4), fleet, T, key,
             forecaster=SeasonalNaiveForecaster(H=4, period=6))),
        ("fleet/congested-uplink/aware",
         lambda: checkified_simulate_fleet(
             NetworkAwareDPPPolicy(), wan, T, key)),
        ("fleet/diurnal-slack/clairvoyant-err",
         lambda: checkified_simulate_fleet(
             LookaheadDPPPolicy(H=4),
             sweep_forecast_errors(fleet, bias=0.05, noise=0.1), T, key,
             forecaster=ClairvoyantTableForecaster(H=4))),
        ("fleet/diurnal-slack+blackout/guard-ci",
         lambda: checkified_simulate_fleet(
             StalenessGuardPolicy(inner=CarbonIntensityPolicy()),
             with_faults(fleet, "regional-blackout"), T, key)),
        ("fleet/congested-uplink+flappy/guard-aware",
         lambda: checkified_simulate_fleet(
             StalenessGuardPolicy(inner=NetworkAwareDPPPolicy()),
             with_faults(wan, "flappy-uplink"), T, key)),
    ]

    # single-instance simulate() path (non-fleet entry point)
    from repro.configs.paper_workloads import paper_spec
    from repro.core.carbon import RandomCarbonSource
    from repro.core.simulator import UniformArrivals

    spec = paper_spec()

    def single(policy):
        def case():
            def run(k):
                return simulate(
                    policy, spec,
                    RandomCarbonSource(N=spec.N),
                    UniformArrivals(M=spec.M), T, k,
                )

            return jax.jit(
                checkify.checkify(run, errors=DEFAULT_CHECKS)
            )(key)

        return case

    cases.append(("single/paper-spec/ci", single(CarbonIntensityPolicy())))
    # fill_chunk < M forces the chunked greedy fill's while_loop; the
    # full check set (incl. OOB) must discharge through it
    cases.append(("single/paper-spec/chunked-fill-while-loop",
                  single(CarbonIntensityPolicy(fill_chunk=2))))

    # single-instance faulted path with the full check set: brownouts +
    # telemetry dropouts + task failures exercise the requeue rounding
    # and the wasted-emissions ledger under OOB instrumentation too
    from repro.faults import make_faults, simulate_faulted

    def single_faulted():
        fp = make_faults(
            spec.N, cloud_p_down=0.05, cloud_p_up=0.3,
            brown_p_start=0.1, brown_p_end=0.2, brown_floor=0.5,
            telem_p_down=0.2, telem_p_up=0.2, task_p_fail=0.1,
        )

        def run(k):
            return simulate_faulted(
                StalenessGuardPolicy(inner=CarbonIntensityPolicy()),
                spec, fp, RandomCarbonSource(N=spec.N),
                UniformArrivals(M=spec.M), T, k,
            )

        return jax.jit(checkify.checkify(run, errors=DEFAULT_CHECKS))(key)

    cases.append(("single/paper-spec+faults/guard-ci", single_faulted))

    # deadline layer with the full check set: slack math runs through
    # +inf (empty queues / no deadline) and the admission cap through
    # an inf branch -- both must stay NaN- and div-by-zero-free with
    # shedding active, and the age-ring scatter in-bounds
    def single_deadlines():
        import numpy as np

        from repro.deadlines import SlackThresholdPolicy, make_deadlines

        dl = make_deadlines(
            spec.M,
            deadline=np.array([1.0, 3.0, np.inf, 2.0, np.inf],
                              np.float32)[: spec.M],
            window=2.0, shed_on=1.0, headroom=0.8,
        )

        def run(k):
            return simulate(
                SlackThresholdPolicy(), spec,
                RandomCarbonSource(N=spec.N),
                UniformArrivals(M=spec.M), T, k, deadlines=dl,
            )

        return jax.jit(checkify.checkify(run, errors=DEFAULT_CHECKS))(key)

    cases.append(("single/paper-spec+deadlines/slack-shed",
                  single_deadlines))

    results: List[Tuple[str, str | None]] = []
    for name, runner in cases:
        try:
            err, res = runner()
            jax.block_until_ready(res)
            results.append((name, err.get()))
        except Exception as e:  # checkify lift itself failed
            results.append((name, f"checkify lift failed: {e}"))
    return results
