"""Repo-specific AST lint (stdlib ``ast`` only -- no third-party deps).

Rules target the hazards that have actually bitten this codebase, not
general style (ruff covers that; see ``[tool.ruff]`` in pyproject.toml):

  host-cast       ``float(...)`` / ``int(...)`` applied to a jnp/jax
                  expression, or any ``.item()`` call, inside a jitted
                  package: both force a device sync and break tracing.
  jnp-for         Python ``for`` iterating a ``jnp.``/``jax.numpy``
                  expression in a hot-path package -- an O(n) unrolled
                  trace where ``lax.scan``/``vmap`` belongs.
  pltpu-import    direct ``jax.experimental.pallas.tpu`` import outside
                  ``kernels/compat.py``: the compat shim exists because
                  the pltpu API drifts across JAX versions (PR 1 found
                  27 kernel tests broken by exactly this).
  np-in-scan      ``np.`` reference inside a function passed to
                  ``lax.scan`` / ``while_loop`` / ``fori_loop`` /
                  ``cond``: numpy silently constant-folds under trace
                  (or promotes to float64), corrupting the carry.
  mutable-default mutable default argument values.
  unused-import   module-level import never referenced (skipped in
                  ``__init__.py`` re-export modules; names listed in
                  ``__all__`` count as used).

Suppress a finding with a trailing ``# lint: allow=<rule>`` comment (or
``# lint: allow`` for all rules on that line). Pre-existing accepted
findings live in ``analysis/baseline.json``; the CLI only fails on NEW
violations relative to it.

The host-cast / jnp-for / np-in-scan rules apply to the traced-hot-path
packages (``core``, ``network``, ``forecast``, ``kernels``) -- host-side
numpy oracles (``literal_algorithm1``, the ``oracle_*`` bounds, CSV
loaders) are recognized by their ``np.`` usage and exempted from
host-cast, since numpy IS their point. Files outside ``src/repro`` (the
seeded-violation fixtures under ``tests/fixtures/lint``) get every rule.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, List, Sequence

# Packages whose module bodies are (mostly) traced by jit/scan/vmap.
JITTED_PACKAGES = ("core", "network", "forecast", "kernels")

RULES = (
    "host-cast",
    "jnp-for",
    "pltpu-import",
    "np-in-scan",
    "mutable-default",
    "unused-import",
)

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow(?:=([\w,-]+))?")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    @property
    def key(self) -> str:
        return f"{self.path}::{self.rule}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _allowed_rules(source_line: str) -> set | None:
    """Returns the set of rules suppressed on this line (empty set =
    all rules), or None when the line carries no suppression."""
    m = _ALLOW_RE.search(source_line)
    if m is None:
        return None
    if m.group(1) is None:
        return set()
    return {r.strip() for r in m.group(1).split(",")}


def _attr_root(node: ast.AST) -> str | None:
    """Root name of an attribute chain: ``jnp.sum`` -> ``jnp``."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _contains_traced_ref(node: ast.AST) -> bool:
    """Does this expression reference jnp / jax / lax machinery?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute):
            if _attr_root(sub) in ("jnp", "jax", "lax"):
                return True
        elif isinstance(sub, ast.Name) and sub.id in ("jnp", "lax"):
            return True
    return False


def _uses_numpy(fn: ast.AST) -> bool:
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Attribute) and _attr_root(sub) == "np":
            return True
    return False


def _is_scan_like(call: ast.Call) -> bool:
    """Matches lax.scan / jax.lax.scan / while_loop / fori_loop / cond."""
    func = call.func
    if not isinstance(func, ast.Attribute):
        return False
    if func.attr not in ("scan", "while_loop", "fori_loop", "cond"):
        return False
    return _attr_root(func) in ("lax", "jax")


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str, source: str, active: Sequence[str],
                 compat_module: bool):
        self.path = path
        self.lines = source.splitlines()
        self.active = set(active)
        self.compat_module = compat_module
        self.violations: List[LintViolation] = []
        # stack of enclosing FunctionDef nodes
        self._fn_stack: List[ast.AST] = []
        # function names handed to scan-like combinators, per module
        self._scan_fn_names: set = set()
        self._local_fns: dict = {}

    # -- helpers ----------------------------------------------------------
    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        if rule not in self.active:
            return
        line = getattr(node, "lineno", 1)
        src = self.lines[line - 1] if line - 1 < len(self.lines) else ""
        allowed = _allowed_rules(src)
        if allowed is not None and (not allowed or rule in allowed):
            return
        self.violations.append(
            LintViolation(self.path, line, rule, message)
        )

    def _in_host_fn(self) -> bool:
        """Host-side oracle heuristic: the enclosing function leans on
        numpy, so float()/int() concretization is its normal mode."""
        return bool(self._fn_stack) and _uses_numpy(self._fn_stack[-1])

    # -- rules ------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name.startswith("jax.experimental.pallas.tpu"):
                if not self.compat_module:
                    self._emit(
                        node, "pltpu-import",
                        "direct pltpu import bypasses kernels/compat.py "
                        "(import CompilerParams/VMEM from repro.kernels."
                        "compat instead)",
                    )
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module and (
            node.module.startswith("jax.experimental.pallas.tpu")
            or (node.module == "jax.experimental.pallas"
                and any(a.name == "tpu" for a in node.names))
        ):
            if not self.compat_module:
                self._emit(
                    node, "pltpu-import",
                    "direct pltpu import bypasses kernels/compat.py",
                )
        self.generic_visit(node)

    def _check_defaults(self, node) -> None:
        for default in list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]:
            mutable = isinstance(default, (ast.List, ast.Dict, ast.Set))
            if isinstance(default, ast.Call):
                callee = default.func
                if isinstance(callee, ast.Name) and callee.id in (
                    "list", "dict", "set", "bytearray"
                ):
                    mutable = True
            if mutable:
                self._emit(
                    default, "mutable-default",
                    f"mutable default argument in {node.name}() is shared "
                    "across calls",
                )

    def _visit_fn(self, node) -> None:
        self._check_defaults(node)
        self._local_fns[node.name] = node
        self._fn_stack.append(node)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        # float(jnp...) / int(jnp...): concretizes a traced value
        if (
            isinstance(func, ast.Name)
            and func.id in ("float", "int", "bool")
            and node.args
            and _contains_traced_ref(node.args[0])
            and not self._in_host_fn()
        ):
            self._emit(
                node, "host-cast",
                f"{func.id}() on a traced jnp/jax expression forces a "
                "host sync and breaks tracing",
            )
        # .item() anywhere in a jitted module
        if (
            isinstance(func, ast.Attribute)
            and func.attr == "item"
            and not node.args
            and not self._in_host_fn()
        ):
            self._emit(
                node, "host-cast",
                ".item() concretizes a traced value (host sync)",
            )
        # record functions handed to scan-like combinators
        if _is_scan_like(node) and node.args:
            target = node.args[0]
            if isinstance(target, ast.Name):
                self._scan_fn_names.add(target.id)
            elif isinstance(target, (ast.Lambda,)):
                self._check_np_in_body(target)
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if _contains_traced_ref(node.iter):
            self._emit(
                node, "jnp-for",
                "Python for-loop over a jnp expression unrolls the "
                "trace; use lax.scan / vmap",
            )
        self.generic_visit(node)

    def _check_np_in_body(self, fn: ast.AST) -> None:
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Attribute) and _attr_root(sub) == "np":
                self._emit(
                    sub, "np-in-scan",
                    "np.* inside a scan/while/cond body constant-folds "
                    "under trace (and may promote to float64); use jnp",
                )

    def finish(self, tree: ast.Module) -> None:
        # second pass: np. usage inside functions passed to scan-likes
        for name in self._scan_fn_names:
            fn = self._local_fns.get(name)
            if fn is not None:
                self._check_np_in_body(fn)
        self._check_unused_imports(tree)

    # -- unused imports ---------------------------------------------------
    def _check_unused_imports(self, tree: ast.Module) -> None:
        if "unused-import" not in self.active:
            return
        if Path(self.path).name == "__init__.py":
            return  # re-export modules: imports ARE the public API
        imported: dict = {}  # bound name -> node
        for node in tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    imported[bound] = node
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    bound = alias.asname or alias.name
                    imported[bound] = node
        if not imported:
            return
        used: set = set()
        for sub in ast.walk(tree):
            if isinstance(sub, ast.Name) and not isinstance(
                sub.ctx, ast.Store
            ):
                used.add(sub.id)
            elif isinstance(sub, ast.Attribute):
                root = _attr_root(sub)
                if root is not None:
                    used.add(root)
            elif isinstance(sub, ast.Constant) and isinstance(
                sub.value, str
            ):
                # __all__ entries / forward-reference annotations
                used.add(sub.value)
        for bound, node in imported.items():
            if bound not in used:
                self._emit(
                    node, "unused-import",
                    f"imported name {bound!r} is never used",
                )


def _rules_for(path: Path, root: Path | None) -> tuple:
    """Which rules apply to this file. Inside src/repro the traced-path
    rules are limited to the jitted packages; anywhere else (tests,
    fixtures, benchmarks) every rule applies."""
    everywhere = ("pltpu-import", "mutable-default", "unused-import")
    if root is not None:
        try:
            rel = path.resolve().relative_to(root.resolve())
        except ValueError:
            return everywhere
        parts = rel.parts
        if len(parts) >= 1 and parts[0] in JITTED_PACKAGES:
            return RULES
        return everywhere
    return RULES


def lint_file(path: Path, root: Path | None = None) -> List[LintViolation]:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [LintViolation(str(path), e.lineno or 1, "syntax",
                              f"unparsable: {e.msg}")]
    active = _rules_for(path, root)
    compat = path.name == "compat.py" and path.parent.name == "kernels"
    linter = _FileLinter(str(path), source, active, compat)
    linter.visit(tree)
    linter.finish(tree)
    return linter.violations


def lint_paths(paths: Iterable[Path | str],
               root: Path | None = None) -> List[LintViolation]:
    out: List[LintViolation] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                out.extend(lint_file(f, root=root))
        else:
            out.extend(lint_file(p, root=root))
    return out


def lint_repo(repo_root: Path | str | None = None) -> List[LintViolation]:
    """Lints src/ + tests/ + benchmarks/ + examples/ with the scoping
    described in the module docstring (fixture files are excluded --
    they exist to violate)."""
    repo = Path(repo_root) if repo_root else _find_repo_root()
    src_repro = repo / "src" / "repro"
    out = lint_paths([src_repro], root=src_repro)
    for extra in ("tests", "benchmarks", "examples"):
        d = repo / extra
        if not d.is_dir():
            continue
        for f in sorted(d.rglob("*.py")):
            if "__pycache__" in f.parts or "fixtures" in f.parts:
                continue
            # outside src/repro only the everywhere-rules apply
            out.extend(lint_file(f, root=src_repro))
    return out


def _find_repo_root() -> Path:
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "pyproject.toml").exists():
            return parent
    return here.parents[3]
