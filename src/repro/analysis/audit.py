"""Jaxpr invariant auditor.

Abstractly traces every registered (policy x backend x scenario)
combination -- no simulation is executed -- and checks the invariants
the repo's perf and parity claims rest on:

  dtype discipline   no 64-bit value anywhere in a traced hot path
                     under the repo's default config, and no float64
                     anywhere when the same program is re-traced with
                     x64 enabled (the mode that exposes unpinned
                     ``jax.random.*`` / ``jnp.zeros`` defaults that
                     float32 discipline currently only masks).
  scan carries       every ``lax.scan`` / ``while_loop`` carry leaf is
                     exactly {float32, int32, uint32, bool} and never
                     weak-typed: a weak carry re-types with context and
                     is a silent-retrace hazard.
  effect freedom     no host callbacks (``io_callback`` /
                     ``pure_callback`` / ``debug_callback``) and no
                     JAX effects at all inside the traced program --
                     the fleet scan must stay a pure compiled loop.
                     The ONE sanctioned exception is the opt-in
                     streaming-telemetry flush (telemetry.stream):
                     combos named in ``EFFECTFUL_ALLOWLIST`` may carry
                     ``io_callback`` and its IO effect, nothing else,
                     and every other check still applies to them. A
                     streaming combo absent from the allowlist fails
                     the audit -- the default path stays provably
                     callback-free.
  retrace audit      across the full scenario registry, each
                     (policy, backend) presents exactly ONE abstract
                     input signature per shape class, and the policy
                     object itself is hashable and reconstructible-
                     equal -- together the preconditions for "compiles
                     exactly once per shape class" under ``jax.jit``.

``audit_all()`` runs everything; ``python -m repro.analysis --audit``
is the CLI entry. See DESIGN.md §Static analysis.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Iterable, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.35 exposes the stable surface
    from jax.extend import core as jcore
except ImportError:  # pragma: no cover - older jax
    from jax import core as jcore  # type: ignore

# Primitives that reach back to the host from inside a jitted program.
CALLBACK_PRIMITIVES = {
    "io_callback",
    "pure_callback",
    "debug_callback",
    "outside_call",
    "host_callback_call",
}

# The only dtypes allowed to live in a scan/while carry: the simulator
# contract is float32 state + int32 counters + uint32 PRNG keys + bool
# flags (core/queueing.py DTYPE).
ALLOWED_CARRY_DTYPES = {"float32", "int32", "uint32", "bool"}

# Combos allowed to carry the streaming-telemetry io_callback (and the
# IO effect it hoists onto enclosing scan/pjit eqns) -- the explicit
# registration DESIGN.md §Live observability requires. Populated next
# to the streaming combos in iter_combos; anything else tracing an
# io_callback (including an unregistered StreamConfig combo) still
# fails the effects check.
EFFECTFUL_ALLOWLIST: set = set()

AUDIT_T = 8          # slots traced per combo (tracing cost only)
AUDIT_M, AUDIT_N = 4, 3
AUDIT_TC = 24
AUDIT_PER_KIND = 2


@dataclasses.dataclass(frozen=True)
class AuditViolation:
    combo: str
    check: str   # "dtype64" | "weak-carry" | "carry-dtype" | "effects" | "x64" | "retrace"
    message: str

    def __str__(self) -> str:
        return f"{self.combo}: [{self.check}] {self.message}"


class Combo(NamedTuple):
    """One traceable (policy, forecaster, scenario-family) combination."""

    name: str
    policy_key: str        # retrace-grouping key: policy x backend
    scenario: str
    make_policy: Callable  # () -> policy (called twice: equality check)
    forecaster: object
    fleet: object          # FleetScenario
    record: object         # "full" | "summary" | int stride
    telemetry: object = None  # TelemetryConfig | None (jit static)


# ---------------------------------------------------------------------------
# Registry enumeration


def _policy_factories():
    from repro.core.extensions import ThresholdPolicy
    from repro.core.policies import (
        CarbonIntensityPolicy,
        ExactDPPPolicy,
        LookaheadDPPPolicy,
        QueueLengthPolicy,
        RandomPolicy,
    )
    from repro.forecast import SeasonalNaiveForecaster

    fc = SeasonalNaiveForecaster(H=4, period=6)
    return [
        # (policy_key, factory, forecaster)
        ("ci/reference", lambda: CarbonIntensityPolicy(), None),
        ("ci/pallas",
         lambda: CarbonIntensityPolicy(score_backend="pallas"), None),
        ("queue-length", lambda: QueueLengthPolicy(), None),
        ("lookahead/reference", lambda: LookaheadDPPPolicy(H=4), fc),
        ("threshold", lambda: ThresholdPolicy(), None),
        ("random", lambda: RandomPolicy(), None),
        ("exact-dpp", lambda: ExactDPPPolicy(grid=32), None),
    ]


def _wan_policy_factories():
    from repro.core.policies import CarbonIntensityPolicy
    from repro.forecast import SeasonalNaiveForecaster
    from repro.network import NetworkAwareDPPPolicy, StaticRoutePolicy

    fc = SeasonalNaiveForecaster(H=4, period=6)
    return [
        ("aware/reference", lambda: NetworkAwareDPPPolicy(), None),
        ("aware/pallas",
         lambda: NetworkAwareDPPPolicy(score_backend="pallas"), None),
        ("blind",
         lambda: StaticRoutePolicy(CarbonIntensityPolicy()), None),
        ("aware-lookahead/reference",
         lambda: NetworkAwareDPPPolicy(H=4), fc),
    ]


def iter_combos(per_kind: int = AUDIT_PER_KIND) -> List[Combo]:
    """Every (policy x backend) crossed with every registered scenario
    (plain fleets) and every registered topology (WAN fleets), at audit
    size. One representative per (policy, scenario) additionally audits
    the "summary" and stride recording modes."""
    from repro.configs.fleet_scenarios import (
        NETWORK_SCENARIOS,
        SCENARIOS,
        build_fleet,
        build_network_fleet,
    )
    from repro.core.simulator import sweep_forecast_errors
    from repro.forecast import ClairvoyantTableForecaster

    combos: List[Combo] = []
    fleets = {
        kind: build_fleet([kind], per_kind=per_kind, M=AUDIT_M,
                          N=AUDIT_N, Tc=AUDIT_TC, seed=0)
        for kind in SCENARIOS
    }
    for policy_key, make, fc in _policy_factories():
        for kind, fleet in fleets.items():
            combos.append(Combo(
                name=f"{policy_key}@{kind}",
                policy_key=policy_key, scenario=kind,
                make_policy=make, forecaster=fc, fleet=fleet,
                record="full",
            ))
    # recording-mode coverage (same policy+scenario, different program)
    base = fleets["diurnal-slack"]
    for record in ("summary", 2):
        combos.append(Combo(
            name=f"ci/reference@diurnal-slack/record={record}",
            policy_key="ci/reference", scenario="diurnal-slack",
            make_policy=_policy_factories()[0][1], forecaster=None,
            fleet=base, record=record,
        ))
    # the per-lane forecast-error sweep axis (traced err_bias/err_noise)
    combos.append(Combo(
        name="lookahead/clairvoyant-err@diurnal-slack",
        policy_key="lookahead/reference", scenario="diurnal-slack+err",
        make_policy=_policy_factories()[3][1],
        forecaster=ClairvoyantTableForecaster(H=4),
        fleet=sweep_forecast_errors(base, bias=0.05, noise=0.1),
        record="full",
    ))

    # WAN topologies: the two 2N-route kinds share a shape class; star
    # (N routes) is its own.
    wan_fleets = {
        kind: build_network_fleet([kind], per_kind=per_kind, M=AUDIT_M,
                                  N=AUDIT_N, Tc=AUDIT_TC, seed=0)
        for kind in NETWORK_SCENARIOS
    }
    for policy_key, make, fc in _wan_policy_factories():
        for kind, fleet in wan_fleets.items():
            combos.append(Combo(
                name=f"{policy_key}@{kind}",
                policy_key=policy_key, scenario=kind,
                make_policy=make, forecaster=fc, fleet=fleet,
                record="full",
            ))

    # Fault-layer combos (repro.faults): stacked FaultParams put the
    # fault chains, staleness/backoff counters and retry pool into the
    # scan carry -- every gate (carry dtypes, weak types, x64 re-trace,
    # retrace signatures) covers them from day one.
    from repro.configs.fleet_scenarios import with_faults
    from repro.core.policies import CarbonIntensityPolicy
    from repro.faults import StalenessGuardPolicy
    from repro.network import NetworkAwareDPPPolicy

    blackout = with_faults(base, "regional-blackout")
    brownout = with_faults(base, "telemetry-brownout")
    flappy = with_faults(wan_fleets["congested-uplink"], "flappy-uplink")
    fault_combos = [
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "regional-blackout", blackout, "full"),
        ("ci/pallas",
         lambda: CarbonIntensityPolicy(score_backend="pallas"),
         "regional-blackout", blackout, "full"),
        ("guard-ci/reference",
         lambda: StalenessGuardPolicy(CarbonIntensityPolicy()),
         "regional-blackout", blackout, "full"),
        ("guard-ci/reference",
         lambda: StalenessGuardPolicy(CarbonIntensityPolicy()),
         "telemetry-brownout", brownout, "full"),
        ("guard-ci/reference",
         lambda: StalenessGuardPolicy(CarbonIntensityPolicy()),
         "telemetry-brownout/summary", brownout, "summary"),
        ("queue-length", _policy_factories()[2][1],
         "telemetry-brownout", brownout, "full"),
        ("aware/reference", lambda: NetworkAwareDPPPolicy(),
         "flappy-uplink", flappy, "full"),
        ("guard-aware/reference",
         lambda: StalenessGuardPolicy(NetworkAwareDPPPolicy()),
         "flappy-uplink", flappy, "full"),
    ]
    for policy_key, make, scen, fleet, record in fault_combos:
        combos.append(Combo(
            name=f"{policy_key}@diurnal-slack+{scen}",
            policy_key=policy_key, scenario=scen,
            make_policy=make, forecaster=None, fleet=fleet,
            record=record,
        ))

    # Telemetry-on combos (repro.telemetry): taps put TapState in the
    # carry and a stacked TapSeries on the output path -- all four
    # simulator variants must stay effect-free, 32-bit and re-trace
    # clean with the extra accumulators threaded through. Covers both
    # score backends, the record modes, the WAN path and guard+faults.
    from repro.telemetry import TelemetryConfig

    tcfg = TelemetryConfig()
    telemetry_combos = [
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "diurnal-slack+taps", base, "full"),
        ("ci/pallas",
         lambda: CarbonIntensityPolicy(score_backend="pallas"),
         "diurnal-slack+taps", base, "full"),
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "diurnal-slack+taps/summary", base, "summary"),
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "diurnal-slack+taps/stride", base, 2),
        ("aware/reference", lambda: NetworkAwareDPPPolicy(),
         "congested-uplink+taps", wan_fleets["congested-uplink"],
         "full"),
        ("guard-ci/reference",
         lambda: StalenessGuardPolicy(CarbonIntensityPolicy()),
         "telemetry-brownout+taps", brownout, "full"),
        ("guard-aware/reference",
         lambda: StalenessGuardPolicy(NetworkAwareDPPPolicy()),
         "flappy-uplink+taps", flappy, "full"),
    ]
    for policy_key, make, scen, fleet, record in telemetry_combos:
        combos.append(Combo(
            name=f"{policy_key}@{scen}",
            policy_key=policy_key, scenario=scen,
            make_policy=make, forecaster=None, fleet=fleet,
            record=record, telemetry=tcfg,
        ))

    # Deadline-layer combos (repro.deadlines): stacked DeadlineParams
    # put the [M, D] age rings and the mu estimator into the scan carry
    # and three new policies (with a deadline_view kwarg) onto the
    # traced path -- both score backends, the guarded+faulted
    # composition, shedding under overload, and a taps-on run all pass
    # the same gates (carry dtypes, weak types, x64 re-trace, retrace
    # signatures, effect freedom) as every other combo.
    from repro.configs.fleet_scenarios import with_deadlines
    from repro.deadlines import (
        EDDPolicy,
        SlackThresholdPolicy,
        WaitAwhilePolicy,
    )
    from repro.forecast import SeasonalNaiveForecaster

    tight = with_deadlines(base, "tight-uniform")
    shed = with_deadlines(fleets["overload"], "shed-overload")
    tight_blackout = with_deadlines(blackout, "tight-uniform")
    fc4 = SeasonalNaiveForecaster(H=4, period=6)
    deadline_combos = [
        ("slack/reference", lambda: SlackThresholdPolicy(),
         "tight-uniform", tight, "full", None, None),
        ("slack/pallas",
         lambda: SlackThresholdPolicy(score_backend="pallas"),
         "tight-uniform", tight, "full", None, None),
        ("edd", lambda: EDDPolicy(),
         "tight-uniform", tight, "full", None, None),
        ("waitawhile/reference", lambda: WaitAwhilePolicy(H=4),
         "tight-uniform", tight, "full", fc4, None),
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "overload+shed", shed, "summary", None, None),
        ("guard-slack/reference",
         lambda: StalenessGuardPolicy(SlackThresholdPolicy()),
         "tight-uniform+regional-blackout", tight_blackout, "full",
         None, None),
        ("slack/reference", lambda: SlackThresholdPolicy(),
         "tight-uniform+taps", tight, "full", None, tcfg),
    ]
    for policy_key, make, scen, fleet, record, fcst, tel in \
            deadline_combos:
        combos.append(Combo(
            name=f"{policy_key}@{scen}",
            policy_key=policy_key, scenario=scen,
            make_policy=make, forecaster=fcst, fleet=fleet,
            record=record, telemetry=tel,
        ))

    # Streaming-telemetry combos (repro.telemetry.stream): the ONLY
    # registry entries whose traced program may carry an io_callback.
    # Each name is registered in EFFECTFUL_ALLOWLIST; audit_all traces
    # them with allow_io=True, which tolerates exactly the io_callback
    # primitive + IO effect while every other check (carry dtypes, weak
    # types, x64 re-trace, retrace signatures, other callbacks) still
    # applies. flush_every=4 divides AUDIT_T=8 (streaming requires it).
    from repro.telemetry import StreamConfig

    scfg = StreamConfig(taps=tcfg, flush_every=4, channel="audit")
    stream_combos = [
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "diurnal-slack+stream", base, "full"),
        ("ci/pallas",
         lambda: CarbonIntensityPolicy(score_backend="pallas"),
         "diurnal-slack+stream", base, "full"),
        ("ci/reference", lambda: CarbonIntensityPolicy(),
         "diurnal-slack+stream/summary", base, "summary"),
        ("aware/reference", lambda: NetworkAwareDPPPolicy(),
         "congested-uplink+stream", wan_fleets["congested-uplink"],
         "full"),
        ("guard-ci/reference",
         lambda: StalenessGuardPolicy(CarbonIntensityPolicy()),
         "telemetry-brownout+stream", brownout, "full"),
    ]
    for policy_key, make, scen, fleet, record in stream_combos:
        name = f"{policy_key}@{scen}"
        EFFECTFUL_ALLOWLIST.add(name)
        combos.append(Combo(
            name=name, policy_key=policy_key, scenario=scen,
            make_policy=make, forecaster=None, fleet=fleet,
            record=record, telemetry=scfg,
        ))
    return combos


def _combo_fn(combo: Combo) -> Callable:
    """The function the auditor traces: one full fleet simulation."""
    from repro.core.simulator import simulate_fleet

    policy = combo.make_policy()

    def run(fleet, key):
        return simulate_fleet(
            policy, fleet, AUDIT_T, key,
            forecaster=combo.forecaster, record=combo.record,
            telemetry=combo.telemetry,
        )

    return run


# ---------------------------------------------------------------------------
# Jaxpr walking


def _subjaxprs(eqn) -> Iterable:
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jcore.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jcore.Jaxpr):
                yield v


def _iter_eqns(jaxpr) -> Iterable:
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn):
            yield from _iter_eqns(sub)


def _aval_desc(aval) -> str:
    dtype = getattr(aval, "dtype", None)
    weak = getattr(aval, "weak_type", False)
    shape = getattr(aval, "shape", ())
    return f"{dtype}{shape}{' weak' if weak else ''}"


def _scan_carry_avals(eqn) -> List:
    name = eqn.primitive.name
    if name == "scan":
        nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
        return [v.aval for v in eqn.invars[nc:nc + ncar]]
    if name == "while":
        skip = eqn.params["cond_nconsts"] + eqn.params["body_nconsts"]
        return [v.aval for v in eqn.invars[skip:]]
    return []


def _is_io_effect(effect) -> bool:
    return "io" in type(effect).__name__.lower()


def audit_jaxpr(closed_jaxpr, combo_name: str,
                x64_mode: bool = False,
                allow_io: bool = False) -> List[AuditViolation]:
    """Static checks over one traced program (see module docstring).

    `allow_io=True` (set by audit_all for EFFECTFUL_ALLOWLIST combos
    only) tolerates exactly the streaming-telemetry escape hatch: the
    `io_callback` primitive and the IOEffect it hoists onto enclosing
    scan/pjit equations. Every other callback/effect, and every other
    check, is unaffected.
    """
    out: List[AuditViolation] = []
    seen: set = set()

    def emit(check, msg):
        if (check, msg) not in seen:  # dedupe identical findings
            seen.add((check, msg))
            out.append(AuditViolation(combo_name, check, msg))

    for eqn in _iter_eqns(closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMITIVES:
            if not (allow_io and name == "io_callback"):
                emit("effects", f"host callback primitive '{name}' in a "
                     "jitted path")
        elif eqn.effects:
            leaked = [
                e for e in eqn.effects
                if not (allow_io and _is_io_effect(e))
            ]
            if leaked:
                emit("effects",
                     f"primitive '{name}' carries effects {leaked}")
        for var in eqn.outvars:
            dtype = getattr(var.aval, "dtype", None)
            if dtype is None:
                continue
            if jax.dtypes.issubdtype(dtype, jax.dtypes.extended):
                # typed PRNG keys (key<fry> from random_wrap etc.) have
                # no itemsize and are not a width-discipline concern
                continue
            if x64_mode:
                # int64 from arange/iota defaults is jax-canonical under
                # x64; the discipline violation is 64-bit FLOAT compute.
                if jnp.issubdtype(dtype, jnp.floating) and \
                        jnp.dtype(dtype).itemsize >= 8:
                    emit("x64", f"'{name}' produces {dtype} under "
                         "x64: an unpinned float default in the hot "
                         "path")
            elif jnp.dtype(dtype).itemsize >= 8 and not jnp.issubdtype(
                dtype, jnp.complexfloating
            ):
                emit("dtype64", f"'{name}' produces {dtype}")
            elif jnp.issubdtype(dtype, jnp.complexfloating):
                emit("dtype64", f"'{name}' produces complex {dtype}")
        for aval in _scan_carry_avals(eqn):
            dtype = getattr(aval, "dtype", None)
            if dtype is None:
                continue
            if jax.dtypes.issubdtype(dtype, jax.dtypes.extended):
                continue  # typed PRNG key threaded through the carry
            if getattr(aval, "weak_type", False):
                emit("weak-carry",
                     f"{eqn.primitive.name} carry leaf {_aval_desc(aval)} "
                     "is weak-typed (re-types with context; retrace "
                     "hazard)")
            if not x64_mode and str(dtype) not in ALLOWED_CARRY_DTYPES:
                emit("carry-dtype",
                     f"{eqn.primitive.name} carry leaf {_aval_desc(aval)} "
                     f"outside {sorted(ALLOWED_CARRY_DTYPES)}")
    return out


def _with_x64(enabled: bool):
    """Context manager flipping jax_enable_x64 (trace-time only)."""
    import contextlib

    @contextlib.contextmanager
    def ctx():
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", enabled)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", prev)

    return ctx()


def audit_combo(combo: Combo,
                allow_io: bool = False) -> List[AuditViolation]:
    """Traces one combo under the default config AND under x64, and
    runs the static checks on both jaxprs. The x64 trace never executes
    anything -- it exists to surface unpinned float defaults
    (``jax.random.uniform`` / ``jnp.zeros`` without ``dtype=``) that
    default-config float32 canonicalization silently papers over.
    `allow_io` threads to audit_jaxpr (the streaming-combo escape
    hatch; audit_all sets it from EFFECTFUL_ALLOWLIST)."""
    fn = _combo_fn(combo)
    key = jax.random.PRNGKey(0)
    out: List[AuditViolation] = []
    try:
        closed = jax.make_jaxpr(fn)(combo.fleet, key)
    except Exception as e:  # trace failure is itself a finding
        return [AuditViolation(combo.name, "trace",
                               f"default-config trace failed: {e}")]
    out.extend(audit_jaxpr(closed, combo.name, x64_mode=False,
                           allow_io=allow_io))
    with _with_x64(True):
        try:
            closed64 = jax.make_jaxpr(fn)(combo.fleet, key)
        except Exception as e:
            out.append(AuditViolation(
                combo.name, "x64",
                f"trace fails with x64 enabled -- some op re-types with "
                f"the config instead of being pinned to float32: {e}",
            ))
        else:
            out.extend(audit_jaxpr(closed64, combo.name, x64_mode=True,
                                   allow_io=allow_io))
    return out


# ---------------------------------------------------------------------------
# Retrace audit


def _signature(tree, shapes_only: bool = False) -> str:
    leaves, treedef = jax.tree.flatten(tree)
    parts = [str(treedef)]
    for leaf in leaves:
        shape = getattr(leaf, "shape", None)
        if shapes_only:
            parts.append(f"{shape}")
        else:
            parts.append(
                f"{shape}:{getattr(leaf, 'dtype', type(leaf).__name__)}:"
                f"{getattr(leaf, 'weak_type', False)}"
            )
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]


def retrace_audit(combos: List[Combo] | None = None
                  ) -> Tuple[List[AuditViolation], Dict]:
    """Proves each (policy, backend) compiles exactly once per shape
    class across the registry, without tracing anything:

    ``jax.jit``'s cache key is (static closure, input avals). The
    static closure is constant per combo family iff the policy object
    is hashable and a rebuilt copy compares equal -- checked here via
    the factory. The input avals are constant per shape class iff every
    scenario of that shape presents the identical (treedef, shape,
    dtype, weak_type) signature -- checked by hashing. Any scenario
    whose full signature differs from its shape-class peers would
    silently retrace at run time; it is reported before that happens.

    Returns (violations, report) where report maps
    policy_key -> {shape_class_hash: signature_hash}.
    """
    combos = iter_combos() if combos is None else combos
    out: List[AuditViolation] = []
    # policy_key -> shape_class -> {full_sig: [combo names]}
    table: Dict[str, Dict[str, Dict[str, list]]] = {}
    for combo in combos:
        policy = combo.make_policy()
        rebuilt = combo.make_policy()
        try:
            h1, h2 = hash(policy), hash(rebuilt)
        except TypeError as e:
            out.append(AuditViolation(
                combo.name, "retrace",
                f"policy is unhashable ({e}): cannot be a jit static",
            ))
            continue
        if policy != rebuilt or h1 != h2:
            out.append(AuditViolation(
                combo.name, "retrace",
                "rebuilding the policy from identical config yields an "
                "unequal object: every construction would recompile",
            ))
        args = (combo.fleet, jax.random.PRNGKey(0))
        # record/forecaster/telemetry are static closure -> the key
        static = (
            f"{combo.record}|{combo.forecaster!r}|{combo.telemetry!r}"
        )
        full = _signature(args) + f"|{static}"
        shape = _signature(args, shapes_only=True) + f"|{static}"
        slot = table.setdefault(combo.policy_key, {}).setdefault(
            shape, {}
        )
        slot.setdefault(full, []).append(combo.name)
    for policy_key, classes in table.items():
        for shape, sigs in classes.items():
            if len(sigs) > 1:
                names = [n for group in sigs.values() for n in group]
                out.append(AuditViolation(
                    f"{policy_key}", "retrace",
                    f"{len(sigs)} distinct abstract signatures within "
                    f"one shape class (scenarios {names}): dtype or "
                    "weak_type drift between scenarios would trigger "
                    "a silent retrace",
                ))
    report = {
        pk: {shape: next(iter(sigs)) for shape, sigs in classes.items()}
        for pk, classes in table.items()
    }
    return out, report


def audit_all(per_kind: int = AUDIT_PER_KIND,
              trace_all: bool = False) -> List[AuditViolation]:
    """The full audit: retrace audit over every registry combo (cheap,
    no tracing) + jaxpr checks. By default the jaxpr checks trace one
    representative scenario per (policy_key, shape-class) -- the traced
    program is scenario-independent within a shape class, which is
    exactly what the retrace audit proves first. ``trace_all=True``
    traces every combo (slow; belt-and-braces mode)."""
    combos = iter_combos(per_kind=per_kind)
    violations, _ = retrace_audit(combos)
    if trace_all:
        rep = combos
    else:
        seen: set = set()
        rep = []
        for combo in combos:
            k = (combo.policy_key,
                 _signature((combo.fleet,), shapes_only=True),
                 str(combo.record), repr(combo.forecaster),
                 repr(combo.telemetry))
            if k not in seen:
                seen.add(k)
                rep.append(combo)
    for combo in rep:
        violations.extend(audit_combo(
            combo, allow_io=combo.name in EFFECTFUL_ALLOWLIST
        ))
    return violations
