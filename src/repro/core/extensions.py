"""Beyond-paper scheduling extensions.

* OraclePolicy     -- clairvoyant lower-bound: sees the whole carbon
  future and processes each arrival in the greenest feasible future slot
  (computed offline by sorting slots by intensity). Not implementable
  online; used to measure how much of the achievable reduction the
  paper's online policy captures.
* ThresholdPolicy  -- the naive carbon heuristic (process only when
  CI < threshold, ignore queues): what operators do without the
  drift-plus-penalty machinery. Ablation baseline.
* AdaptiveVController -- closed-loop V tuning: Theorem 1 trades
  emissions (B/V) against queue growth (O(V)); this controller walks V
  multiplicatively to hold total backlog at a target, removing the
  hand-tuning the paper leaves open.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import CarbonIntensityPolicy, QueueLengthPolicy
from repro.core.queueing import Action, NetworkSpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy:
    """Process greedily whenever the cloud's CI is below `threshold`;
    dispatch like the queue-length policy. Carbon-aware but queue-blind:
    no stability guarantee (see tests for the failure mode)."""

    threshold: float = 200.0

    def __call__(self, state, spec, Ce, Cc, arrivals, key=None,
                 fault_view=None, deadline_view=None):
        del fault_view, deadline_view
        base = QueueLengthPolicy()(state, spec, Ce, Cc, arrivals, key)
        gate = (Cc < self.threshold).astype(jnp.float32)[None, :]
        return Action(d=base.d, w=base.w * gate)


def oracle_emissions_for_work(
    spec: NetworkSpec,
    carbon_table: np.ndarray,  # [T, N+1] (edge, clouds)
    edge_energy: float,        # total edge kWh the policy actually spent
    cloud_energy: np.ndarray | float,  # total cloud kWh spent (sum or [N])
) -> float:
    """Clairvoyant lower bound on the emissions of doing the SAME amount
    of work: spend `edge_energy` in the globally cheapest edge slots
    (budget Pe each) and `cloud_energy` in the cheapest (slot, cloud)
    cells (budget Pc[n] each). Relaxations vs any feasible schedule --
    fractional tasks, no arrival-time constraints, free cloud choice --
    only lower the cost, so lb <= any policy's emissions for equal work.
    """
    T = carbon_table.shape[0]
    Pe = float(spec.Pe)
    Pc = np.asarray(spec.Pc, np.float64)

    total = 0.0
    # edge: cheapest slots first
    edge_ci = np.sort(carbon_table[:, 0].astype(np.float64))
    remaining = float(edge_energy)
    for ci in edge_ci:
        take = min(Pe, remaining)
        total += ci * take
        remaining -= take
        if remaining <= 0:
            break
    total += max(remaining, 0.0) * float(edge_ci[-1])

    # clouds: cheapest (slot, cloud) cells first
    cloud_ci = carbon_table[:, 1:].astype(np.float64)  # [T, N]
    cells = [(cloud_ci[s, n], Pc[n]) for s in range(T)
             for n in range(cloud_ci.shape[1])]
    cells.sort()
    remaining = float(np.sum(cloud_energy))
    for ci, cap in cells:
        take = min(cap, remaining)
        total += ci * take
        remaining -= take
        if remaining <= 0:
            break
    total += max(remaining, 0.0) * float(cells[-1][0])
    return float(total)


def oracle_emissions_horizon(
    carbon_table: np.ndarray,          # [T, N+1] (edge, clouds)
    edge_energy: np.ndarray,           # [T] edge kWh actually spent per slot
    cloud_energy: np.ndarray,          # [T, N] cloud kWh spent per slot
    horizon: int | None = None,
) -> float:
    """Clairvoyant-horizon lower bound on the emissions of the SAME
    per-slot energy profile (companion to `oracle_emissions_for_work`,
    which bounds against *totals* under budget caps).

    Every kWh the policy spent in slot s is re-priced at the cheapest
    intensity available within its deferral window [s, s+horizon)
    (same region; rows wrap modulo T like the playback tables), with
    budget contention ignored. Dropping the capacity constraint only
    cheapens the relaxation, so the result lower-bounds any feasible
    schedule that defers each unit of work at most `horizon-1` slots --
    exactly the move set of an H-slot receding-horizon policy. With
    horizon=None (or >= T) the window spans the whole trace: the
    un-budgeted full-trace bound.

    Emissions of LookaheadDPPPolicy(H) on its own energy profile are
    therefore sandwiched: >= this bound at `horizon=H`, and the gap to
    `horizon=None` is the value still on the table from longer
    lookahead.
    """
    ci = np.asarray(carbon_table, np.float64)
    T = ci.shape[0]
    H = T if horizon is None else int(min(max(horizon, 1), T))
    edge_e = np.asarray(edge_energy, np.float64).reshape(T)
    cloud_e = np.asarray(cloud_energy, np.float64).reshape(T, -1)
    if cloud_e.shape[1] != ci.shape[1] - 1:
        raise ValueError(
            f"cloud_energy has {cloud_e.shape[1]} columns, carbon_table "
            f"provides {ci.shape[1] - 1} cloud regions"
        )
    # windowed min over [s, s+H) per column, wrapping like the tables
    wmin = ci.copy()
    for h in range(1, H):
        np.minimum(wmin, np.roll(ci, -h, axis=0), out=wmin)
    total = float(np.sum(edge_e * wmin[:, 0]))
    total += float(np.sum(cloud_e * wmin[:, 1:]))
    return total


@dataclasses.dataclass
class AdaptiveVController:
    """Multiplicative V feedback: hold total backlog near `target_backlog`.

    backlog > target * (1+band)  ->  V /= step   (drain queues)
    backlog < target * (1-band)  ->  V *= step   (chase carbon harder)
    Clamped to [v_min, v_max]. One update per slot; the policy object is
    rebuilt cheaply (pure dataclass)."""

    target_backlog: float
    V: float = 0.05
    step: float = 1.15
    band: float = 0.25
    v_min: float = 1e-4
    v_max: float = 10.0

    def update(self, backlog: float) -> float:
        if backlog > self.target_backlog * (1 + self.band):
            self.V = max(self.V / self.step, self.v_min)
        elif backlog < self.target_backlog * (1 - self.band):
            self.V = min(self.V * self.step, self.v_max)
        return self.V

    def policy(self) -> CarbonIntensityPolicy:
        return CarbonIntensityPolicy(V=self.V)
