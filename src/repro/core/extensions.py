"""Beyond-paper scheduling extensions.

* OraclePolicy     -- clairvoyant lower-bound: sees the whole carbon
  future and processes each arrival in the greenest feasible future slot
  (computed offline by sorting slots by intensity). Not implementable
  online; used to measure how much of the achievable reduction the
  paper's online policy captures.
* ThresholdPolicy  -- the naive carbon heuristic (process only when
  CI < threshold, ignore queues): what operators do without the
  drift-plus-penalty machinery. Ablation baseline.
* AdaptiveVController -- closed-loop V tuning: Theorem 1 trades
  emissions (B/V) against queue growth (O(V)); this controller walks V
  multiplicatively to hold total backlog at a target, removing the
  hand-tuning the paper leaves open.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import CarbonIntensityPolicy, QueueLengthPolicy
from repro.core.queueing import Action, NetworkSpec, NetworkState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ThresholdPolicy:
    """Process greedily whenever the cloud's CI is below `threshold`;
    dispatch like the queue-length policy. Carbon-aware but queue-blind:
    no stability guarantee (see tests for the failure mode)."""

    threshold: float = 200.0

    def __call__(self, state, spec, Ce, Cc, arrivals, key=None):
        base = QueueLengthPolicy()(state, spec, Ce, Cc, arrivals, key)
        gate = (Cc < self.threshold).astype(jnp.float32)[None, :]
        return Action(d=base.d, w=base.w * gate)


def oracle_emissions_for_work(
    spec: NetworkSpec,
    carbon_table: np.ndarray,  # [T, N+1] (edge, clouds)
    edge_energy: float,        # total edge kWh the policy actually spent
    cloud_energy: np.ndarray | float,  # total cloud kWh spent (sum or [N])
) -> float:
    """Clairvoyant lower bound on the emissions of doing the SAME amount
    of work: spend `edge_energy` in the globally cheapest edge slots
    (budget Pe each) and `cloud_energy` in the cheapest (slot, cloud)
    cells (budget Pc[n] each). Relaxations vs any feasible schedule --
    fractional tasks, no arrival-time constraints, free cloud choice --
    only lower the cost, so lb <= any policy's emissions for equal work.
    """
    T = carbon_table.shape[0]
    Pe = float(spec.Pe)
    Pc = np.asarray(spec.Pc, np.float64)

    total = 0.0
    # edge: cheapest slots first
    edge_ci = np.sort(carbon_table[:, 0].astype(np.float64))
    remaining = float(edge_energy)
    for ci in edge_ci:
        take = min(Pe, remaining)
        total += ci * take
        remaining -= take
        if remaining <= 0:
            break
    total += max(remaining, 0.0) * float(edge_ci[-1])

    # clouds: cheapest (slot, cloud) cells first
    cloud_ci = carbon_table[:, 1:].astype(np.float64)  # [T, N]
    cells = [(cloud_ci[s, n], Pc[n]) for s in range(T)
             for n in range(cloud_ci.shape[1])]
    cells.sort()
    remaining = float(np.sum(cloud_energy))
    for ci, cap in cells:
        take = min(cap, remaining)
        total += ci * take
        remaining -= take
        if remaining <= 0:
            break
    total += max(remaining, 0.0) * float(cells[-1][0])
    return float(total)


@dataclasses.dataclass
class AdaptiveVController:
    """Multiplicative V feedback: hold total backlog near `target_backlog`.

    backlog > target * (1+band)  ->  V /= step   (drain queues)
    backlog < target * (1-band)  ->  V *= step   (chase carbon harder)
    Clamped to [v_min, v_max]. One update per slot; the policy object is
    rebuilt cheaply (pure dataclass)."""

    target_backlog: float
    V: float = 0.05
    step: float = 1.15
    band: float = 0.25
    v_min: float = 1e-4
    v_max: float = 10.0

    def update(self, backlog: float) -> float:
        if backlog > self.target_backlog * (1 + self.band):
            self.V = max(self.V / self.step, self.v_min)
        elif backlog < self.target_backlog * (1 - self.band):
            self.V = min(self.V * self.step, self.v_max)
        return self.V

    def policy(self) -> CarbonIntensityPolicy:
        return CarbonIntensityPolicy(V=self.V)
