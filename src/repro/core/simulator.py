"""Queueing-network simulator (paper §V numerical analysis).

A single `lax.scan` over time slots: observe carbon intensity + arrivals,
act with the policy, account emissions (eq. 5), step the dynamics
(eqs. 7-8). Fully jittable; `simulate_vsweep` vmaps the whole simulation
over a vector of V values (beyond-paper: the paper's Figs. 2/4 tradeoff
curve computed in one compiled call).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.carbon import TableCarbonSource
from repro.core.queueing import (
    Action,
    NetworkSpec,
    NetworkState,
    emissions,
    init_state,
    step,
)
from repro.telemetry.stream import split_telemetry, stream_flush
from repro.telemetry.taps import (
    TelemetryProbe,
    finalize_taps,
    init_taps,
    step_taps,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class UniformArrivals:
    """a_m(t) ~ U{0..amax} i.i.d. (paper §V uses amax=400)."""

    M: int
    amax: int = 400

    def __call__(self, t: Array, key: Array) -> Array:
        k = jax.random.fold_in(key, t)
        return jax.random.randint(k, (self.M,), 0, self.amax + 1).astype(
            jnp.float32
        )

    @property
    def a_max(self) -> float:
        return float(self.amax)


@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """a_m(t) ~ Poisson(rate_m), clipped at `clip` to keep a_m bounded
    (Lemma 1 requires bounded arrivals)."""

    rates: tuple
    clip: int = 2000

    def __call__(self, t: Array, key: Array) -> Array:
        k = jax.random.fold_in(key, t)
        lam = jnp.asarray(self.rates, jnp.float32)
        return jnp.minimum(
            jax.random.poisson(k, lam).astype(jnp.float32), float(self.clip)
        )

    @property
    def a_max(self) -> float:
        return float(self.clip)


def init_forecaster_carry(forecaster, N, key, carbon_source, error_params):
    """Builds the forecaster's scan carry the one canonical way (shared
    by `simulate` and the WAN `simulate_network`): hand over the carbon
    key, the playback table when the source carries one, and the
    per-run (bias, noise) ForecastErrorModel override when given --
    omitted entirely otherwise so third-party forecasters without an
    `error` kwarg keep working."""
    init_kwargs = {}
    if error_params is not None:
        init_kwargs["error"] = error_params
    return forecaster.init(
        N,
        key=key,
        table=getattr(carbon_source, "table", None),
        **init_kwargs,
    )


class SimResult(NamedTuple):
    emissions: Array      # [T] per-slot carbon emissions C(t)
    cum_emissions: Array  # [T] cumulative sum
    Qe: Array             # [R, M] edge queue trajectory (post-step)
    Qc: Array             # [R, M, N] cloud queue trajectory (post-step)
    dispatched: Array     # [T] total tasks dispatched
    processed: Array      # [T] total tasks processed
    energy_edge: Array    # [T] edge energy spent
    energy_cloud: Array   # [T, N] cloud energy spent
    telemetry: object = None  # repro.telemetry.Telemetry frame, or None
    deadlines: object = None  # repro.deadlines.DeadlineLedger, or None

    # R depends on the `record` mode: T for "full" (every slot), 1 for
    # "summary" (final state only), T//k for stride k (state at the end
    # of every k-th slot). Scalar series always cover all T slots, and
    # Qe[-1]/Qc[-1] is the final state in every mode.

    @property
    def final_backlog(self) -> Array:
        return self.Qe[-1].sum() + self.Qc[-1].sum()


def _record_scan(body, state_of, carry0, T, record,
                 stream=None, lane=None):
    """Shared scan driver for the recording modes.

    `body(carry, t) -> (carry, scalars)` runs one slot and emits the
    per-slot scalar tuple; `state_of(carry)` extracts the (large) queue
    trajectories to record. Modes:

    * "full"    -- one scan, states recorded every slot ([T, ...]).
    * "summary" -- one scan, scalars only; the final state is recorded
      once ([1, ...]), so device memory stops scaling as O(T * state).
    * stride k  -- scan of scans: the inner scan covers k slots of
      scalars, the outer scan snapshots the post-step state once per
      chunk ([T//k, ...] -- the rows "full" records at slots k-1,
      2k-1, ...). Requires k to divide T.

    Per-slot scalar ops are identical in every mode (same `body`), so
    the scalar series agree bitwise across modes; only the recorded
    queue trajectories differ in length.

    `stream` (a telemetry.stream.StreamConfig) turns on live flushes:
    every mode restructures into the stride-style scan of
    T//flush_every chunks and `stream_flush` hands each chunk's stacked
    TapSeries (the last element of the body's scalar tuple -- streaming
    requires taps-on bodies) to the host channel, tagged with `lane`
    (the fleet lane id; 0 when None). The per-slot values are the same
    `body` program, so streamed runs stay bitwise equal to batch runs.
    """
    if stream is not None:
        return _record_scan_streaming(
            body, state_of, carry0, T, record, stream,
            jnp.int32(0) if lane is None else lane,
        )
    if record == "full":
        def with_state(carry, t):
            carry, scalars = body(carry, t)
            return carry, (scalars, state_of(carry))

        carry, (scalars, states) = jax.lax.scan(
            with_state, carry0, jnp.arange(T)
        )
        return scalars, states
    if record == "summary":
        carry, scalars = jax.lax.scan(body, carry0, jnp.arange(T))
        states = jax.tree.map(lambda x: x[None], state_of(carry))
        return scalars, states
    if not isinstance(record, int) or record <= 0 or T % record != 0:
        raise ValueError(
            f"record={record!r} must be 'full', 'summary', or a positive "
            f"int stride dividing T={T}"
        )
    k = record

    def chunk(carry, ts):
        carry, scalars = jax.lax.scan(body, carry, ts)
        return carry, (scalars, state_of(carry))

    carry, (scalars, states) = jax.lax.scan(
        chunk, carry0, jnp.arange(T).reshape(T // k, k)
    )
    scalars = jax.tree.map(
        lambda x: x.reshape((T,) + x.shape[2:]), scalars
    )
    return scalars, states


def _record_scan_streaming(body, state_of, carry0, T, record, stream,
                           lane):
    """The streaming variants of the recording modes: a scan of
    T//flush_every chunks, each an inner scan of `body` followed by one
    unconditional `stream_flush` of the chunk's TapSeries slice. The
    per-slot program is untouched, so scalar outputs stay bitwise equal
    to the non-streaming modes (the stride mode above already proves
    scan-of-scans stacking is value-neutral)."""
    k = stream.flush_every
    if T % k != 0:
        raise ValueError(
            f"streaming needs flush_every={k} to divide T={T}"
        )
    if record not in ("full", "summary"):
        if not isinstance(record, int) or record != k:
            raise ValueError(
                f"streaming runs chunk the scan at flush_every={k}; "
                f"record must be 'full', 'summary', or the stride "
                f"{k} itself (got record={record!r})"
            )
    ts = jnp.arange(T).reshape(T // k, k)

    def flat(x):  # [T//k, k, ...] -> [T, ...]
        return x.reshape((T,) + x.shape[2:])

    if record == "full":
        def with_state(carry, t):
            carry, scalars = body(carry, t)
            return carry, (scalars, state_of(carry))

        def chunk(carry, tsk):
            carry, (scalars, states) = jax.lax.scan(
                with_state, carry, tsk
            )
            stream_flush(stream, lane, tsk[0], scalars[-1])
            return carry, (scalars, states)

        carry, (scalars, states) = jax.lax.scan(chunk, carry0, ts)
        return (jax.tree.map(flat, scalars),
                jax.tree.map(flat, states))

    if record == "summary":
        def chunk(carry, tsk):
            carry, scalars = jax.lax.scan(body, carry, tsk)
            stream_flush(stream, lane, tsk[0], scalars[-1])
            return carry, scalars

        carry, scalars = jax.lax.scan(chunk, carry0, ts)
        states = jax.tree.map(lambda x: x[None], state_of(carry))
        return jax.tree.map(flat, scalars), states

    def chunk(carry, tsk):
        carry, scalars = jax.lax.scan(body, carry, tsk)
        stream_flush(stream, lane, tsk[0], scalars[-1])
        return carry, (scalars, state_of(carry))

    carry, (scalars, states) = jax.lax.scan(chunk, carry0, ts)
    return jax.tree.map(flat, scalars), states


def simulate(
    policy: Callable,
    spec: NetworkSpec,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
    state0: NetworkState | None = None,
    forecaster: Callable | None = None,
    graph=None,
    error_params=None,
    record: str | int = "full",
    faults=None,
    telemetry=None,
    stream_lane=None,
    deadlines=None,
) -> SimResult:
    """Runs the network for T slots under `policy`.

    `record` controls how much trajectory the result carries: "full"
    (default) stacks the post-step queues every slot; "summary" keeps
    only the final state (Qe/Qc come back with a length-1 leading axis,
    so `Qe[-1]` and `final_backlog` work unchanged); an int stride k
    snapshots the state every k-th slot ([T//k, ...]). The per-slot
    scalar series (emissions/dispatched/processed/energy) cover all T
    slots bitwise identically in every mode -- see `_record_scan`.

    When `forecaster` is given (see repro.forecast), its carry threads
    through the scan next to the queue state: every slot the observed
    intensity row updates the forecaster, its [H, N+1] prediction is
    handed to the policy as `forecast=`, and emissions are still
    accounted against the TRUE intensities -- forecast error can only
    mislead the policy, never the ledger. The forecaster sees the
    carbon key (so clairvoyant wrappers predict the realized world) and
    the playback table when the source carries one
    (`carbon_source.table`, e.g. TableCarbonSource / fleet lanes).
    Policies consuming forecasts must accept a `forecast` kwarg
    (LookaheadDPPPolicy does).

    `error_params = (bias, noise)` overrides the forecaster's
    ForecastErrorModel parameters for this run (traced values allowed:
    `simulate_fleet` uses it to sweep forecast quality across vmapped
    lanes; clairvoyant forecasters honor it, statistical ones ignore
    it).

    When `graph` (a repro.network.LinkGraph) is given the run goes
    through the WAN transfer layer instead: the in-flight queue
    Qt [M, L] joins the scan carry, the policy is called with
    `graph=`/`Qt=` keywords and must return a NetAction, and the result
    is a NetSimResult (extra Qt / delivered / energy_transfer fields).

    When `faults` (a repro.faults.FaultParams) is given the run goes
    through the fault layer (repro.faults.sim): outage/brownout/
    telemetry chains join the scan carry, the policy sees observed
    (possibly stale) intensities, capacity-masked budgets and a
    `fault_view=` kwarg, and the result is a FaultSimResult. With
    `faults=None` this body is untouched, and with all fault rates zero
    the faulted body is bitwise-identical to it (tests/test_faults.py).

    `telemetry` (a repro.telemetry.TelemetryConfig, trace-time static)
    turns on the in-scan metrics taps and SLO monitors: the result's
    `.telemetry` field then carries a Telemetry frame of per-slot
    series, run gauges, and structured alert records (DESIGN.md
    §Observability). With `telemetry=None` the tap carry is `()` (zero
    pytree leaves) and the run is bit-identical to a build without the
    telemetry layer -- a standing parity anchor
    (tests/test_telemetry.py, asserted again before bench timing).
    A `repro.telemetry.StreamConfig` additionally flushes TapSeries
    slices to a host channel every `flush_every` slots while the scan
    runs (DESIGN.md §Live observability): same tap values bitwise, but
    the traced program carries an io_callback, so only audit-allowlisted
    combos may stream. `stream_lane` tags those flushes with the fleet
    lane id (set by `simulate_fleet`; defaults to lane 0).

    When `deadlines` (a repro.deadlines.DeadlineParams) is given, the
    age-ringed deadline state joins the scan carry: the policy is
    called with a `deadline_view=` kwarg, overdue tasks expire into the
    result's `.deadlines` ledger (missed/shed/admitted series plus the
    recorded `Qd` rings), admission control may shed arrivals, and the
    telemetry probe's missed/shed fields go live. With
    `deadlines=no_deadlines(M)` (all-infinite, shedding off) every
    shared result field is bitwise-identical to the `deadlines=None`
    run -- the subsystem's standing parity anchor
    (tests/test_deadlines.py).
    """
    if graph is not None:
        from repro.network.sim import simulate_network

        return simulate_network(
            policy, spec, graph, carbon_source, arrival_source, T, key,
            state0=state0, forecaster=forecaster,
            error_params=error_params, record=record, faults=faults,
            telemetry=telemetry, stream_lane=stream_lane,
            deadlines=deadlines,
        )
    if faults is not None:
        from repro.faults.sim import simulate_faulted

        return simulate_faulted(
            policy, spec, faults, carbon_source, arrival_source, T, key,
            state0=state0, forecaster=forecaster,
            error_params=error_params, record=record,
            telemetry=telemetry, stream_lane=stream_lane,
            deadlines=deadlines,
        )
    telemetry, stream = split_telemetry(telemetry)
    pe, pc, _, _ = spec.as_arrays()
    if state0 is None:
        state0 = init_state(spec.M, spec.N)
    if deadlines is not None:
        from repro.deadlines.model import (
            DeadlineLedger,
            deadline_view,
            init_deadlines,
            step_deadlines,
        )
    k_carbon, k_arrive, k_policy = jax.random.split(key, 3)

    if forecaster is not None:
        fcarry0 = init_forecaster_carry(
            forecaster, spec.N, k_carbon, carbon_source, error_params
        )

    def body(carry, t):
        state, fcarry, tap, dstate = carry
        Ce, Cc = carbon_source(t, k_carbon)
        a = arrival_source(t, k_arrive)
        k_t = jax.random.fold_in(k_policy, t)
        pkw = {}
        if deadlines is not None:
            pkw["deadline_view"] = deadline_view(deadlines, dstate)
        if forecaster is None:
            act: Action = policy(state, spec, Ce, Cc, a, k_t, **pkw)
        else:
            fcarry = forecaster.update(
                fcarry, jnp.concatenate([Ce[None], Cc])
            )
            act = policy(
                state, spec, Ce, Cc, a, k_t,
                forecast=forecaster.predict(fcarry, t), **pkw,
            )
        C_t = emissions(spec, act, Ce, Cc)
        if deadlines is None:
            nxt = step(state, act, a)
            missed = shed = jnp.float32(0.0)
        else:
            d_sum = jnp.sum(act.d, axis=1)
            dstate, admitted, expired, shed_v = step_deadlines(
                deadlines, dstate, d_sum, a
            )
            # Same queue update as `step`, with arrivals replaced by
            # (admitted - expired): bitwise `+ a` under the
            # no_deadlines anchor (admitted == a, expired == +0.0).
            nxt = NetworkState(
                Qe=jnp.maximum(state.Qe - d_sum, 0.0)
                + admitted - expired,
                Qc=jnp.maximum(state.Qc - act.w, 0.0) + act.d,
            )
            missed = jnp.sum(expired)
            shed = jnp.sum(shed_v)
        out = (
            C_t,
            jnp.sum(act.d),
            jnp.sum(act.w),
            jnp.sum(act.d * pe[:, None]),
            jnp.sum(act.w * pc, axis=0),
        )
        if deadlines is not None:
            out = out + (missed, shed, jnp.sum(admitted))
        if telemetry is None:
            return (nxt, fcarry, tap, dstate), out
        probe = TelemetryProbe(
            emissions=C_t,
            arrived=jnp.sum(a),
            dispatched=jnp.sum(act.d, axis=0),
            processed=jnp.sum(act.w),
            failed=jnp.float32(0.0),
            wasted=jnp.float32(0.0),
            backlog=jnp.sum(nxt.Qe) + jnp.sum(nxt.Qc),
            stale=jnp.int32(0),
            clouds_down=jnp.float32(0.0),
            retry_depth=jnp.float32(0.0),
            transfer_occupancy=jnp.float32(0.0),
            missed=missed,
            shed=shed,
        )
        tap, tseries = step_taps(telemetry, tap, probe)
        return (nxt, fcarry, tap, dstate), (out, tseries)

    carry0 = (
        state0,
        fcarry0 if forecaster is not None else (),
        init_taps() if telemetry is not None else (),
        init_deadlines(spec.M, deadlines.rings.shape[-1])
        if deadlines is not None else (),
    )
    if deadlines is None:
        state_of = lambda carry: (carry[0].Qe, carry[0].Qc)  # noqa: E731
    else:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[3].Qd
        )
    scalars, states = _record_scan(
        body, state_of, carry0, T,
        record, stream=stream, lane=stream_lane,
    )
    if telemetry is None:
        scal, tel = scalars, None
    else:
        scal, tseries = scalars
        tel = finalize_taps(telemetry, tseries)
    if deadlines is None:
        (C, disp, proc, ee, ec) = scal
        (Qe, Qc), led = states, None
    else:
        (C, disp, proc, ee, ec, missed, shed, adm) = scal
        Qe, Qc, Qd = states
        led = DeadlineLedger(missed=missed, shed=shed, admitted=adm,
                             Qd=Qd)
    return SimResult(
        emissions=C,
        cum_emissions=jnp.cumsum(C),
        Qe=Qe,
        Qc=Qc,
        dispatched=disp,
        processed=proc,
        energy_edge=ee,
        energy_cloud=ec,
        telemetry=tel,
        deadlines=led,
    )


def simulate_vsweep(
    make_policy: Callable[[Array], Callable],
    Vs: Array,
    spec: NetworkSpec,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
) -> SimResult:
    """vmaps the full simulation over a vector of V values.

    `make_policy(V)` must build a policy whose only V-dependence flows
    through traced arithmetic (CarbonIntensityPolicy qualifies).
    """

    def one(V):
        return simulate(
            make_policy(V), spec, carbon_source, arrival_source, T, key
        )

    return jax.vmap(one)(jnp.asarray(Vs, jnp.float32))


class FleetSpec(NamedTuple):
    """Stacked NetworkSpec arrays; every field has leading fleet axis F."""

    pe: Array  # [F, M]
    pc: Array  # [F, M, N]
    Pe: Array  # [F]
    Pc: Array  # [F, N]


class FleetScenario(NamedTuple):
    """A stack of F independent simulation instances.

    One FleetScenario = one compiled `simulate_fleet` call sweeping F
    region x workload-mix scenarios. Carbon is a playback table per
    instance (col 0 = edge, cols 1..N = clouds; rows repeat modulo the
    table length), arrivals are per-type uniform U{0..amax} draws so the
    whole scenario is a pytree of arrays that vmaps.

    Optional axes (None = feature off for the whole fleet):
      graph     -- a stacked repro.network.LinkGraph (leading axis F):
                   every lane simulates through the WAN transfer layer
                   and the result is a NetSimResult.
      err_bias / err_noise -- [F] per-lane ForecastErrorModel overrides,
                   handed to the forecaster's init as
                   `error=(bias, noise)`: ONE compiled call sweeps
                   forecast quality across lanes.
      faults    -- stacked repro.faults.FaultParams (leading axis F):
                   every lane simulates through the fault layer and the
                   result is a FaultSimResult / NetFaultSimResult. See
                   configs.fleet_scenarios.with_faults for the scenario
                   registry.
      deadlines -- stacked repro.deadlines.DeadlineParams (leading axis
                   F): every lane simulates through the deadline layer
                   (expiry, admission control, `deadline_view=` to the
                   policy) and the result carries a DeadlineLedger. See
                   configs.fleet_scenarios.with_deadlines.
    """

    spec: FleetSpec
    carbon: Array        # [F, Tc, N+1] intensity playback tables
    arrival_amax: Array  # [F, M] per-type uniform arrival caps
    graph: object | None = None       # stacked LinkGraph or None
    err_bias: Array | None = None     # [F] forecast bias per lane
    err_noise: Array | None = None    # [F] forecast noise per lane
    faults: object | None = None      # stacked FaultParams or None
    deadlines: object | None = None   # stacked DeadlineParams or None

    @property
    def F(self) -> int:
        return self.arrival_amax.shape[0]


def stack_scenarios(instances, graphs=None) -> FleetScenario:
    """Stacks an iterable of (NetworkSpec, carbon_table [Tc,N+1],
    amax [M]) triples into one FleetScenario. Tables must share Tc and
    specs must share (M, N). `graphs`, when given, is a parallel
    iterable of LinkGraphs (sharing M, N, L) stacked onto the fleet's
    graph axis."""
    pes, pcs, Pes, Pcs, tabs, amaxs = [], [], [], [], [], []
    for spec, table, amax in instances:
        pe, pc, Pe, Pc = spec.as_arrays()
        pes.append(pe)
        pcs.append(pc)
        Pes.append(Pe)
        Pcs.append(Pc)
        tabs.append(jnp.asarray(table, jnp.float32))
        amaxs.append(jnp.broadcast_to(
            jnp.asarray(amax, jnp.float32), pe.shape
        ))
    fleet = FleetScenario(
        spec=FleetSpec(
            pe=jnp.stack(pes), pc=jnp.stack(pcs),
            Pe=jnp.stack(Pes), Pc=jnp.stack(Pcs),
        ),
        carbon=jnp.stack(tabs),
        arrival_amax=jnp.stack(amaxs),
    )
    if graphs is not None:
        from repro.network.graph import stack_graphs

        fleet = fleet._replace(graph=stack_graphs(list(graphs)))
    return fleet


def sweep_forecast_errors(
    fleet: FleetScenario, bias, noise
) -> FleetScenario:
    """Attaches per-lane ForecastErrorModel parameters ([F] arrays or
    scalars, broadcast) so one compiled `simulate_fleet` call sweeps
    forecast quality across lanes instead of looping configs."""
    F = fleet.F
    return fleet._replace(
        err_bias=jnp.broadcast_to(
            jnp.asarray(bias, jnp.float32), (F,)
        ),
        err_noise=jnp.broadcast_to(
            jnp.asarray(noise, jnp.float32), (F,)
        ),
    )


def simulate_fleet(
    policy: Callable,
    fleet: FleetScenario,
    T: int,
    key: Array,
    forecaster: Callable | None = None,
    record: str | int = "full",
    telemetry=None,
) -> SimResult:
    """Runs F independent network instances for T slots in ONE compiled
    call: the full `simulate` scan is vmapped over the stacked
    (spec, carbon table, arrival caps) axes, so sweeping 64+ scenarios
    costs one compilation and one device dispatch.

    Returns a SimResult whose every field carries a leading fleet axis
    [F, ...] (index before using reductions like `final_backlog`);
    a NetSimResult when the fleet carries a stacked LinkGraph.
    Instance f draws its own arrival/policy randomness from
    `jax.random.split(key, F)[f]`.

    `record` threads through to every lane's `simulate`: full-recording
    fleet memory scales as O(F * T * M * N); `record="summary"` keeps
    only per-slot scalars plus the final state ([F, 1, M] / [F, 1, M, N])
    -- the mode that unlocks F >= 512 lanes in one compiled call.

    `telemetry` threads to every lane: the result's `.telemetry` frame
    carries a leading [F] axis on every field (select one lane with
    `repro.telemetry.lane`, or reduce the fleet with
    `repro.telemetry.manifest`). A StreamConfig streams every lane to
    the same channel with `lane=f` payload tags (the vmapped
    io_callback fires once per lane per chunk with unbatched slices,
    so the tag is the only lane identity a consumer gets); the lane
    axis only joins the vmap when streaming is on, keeping the
    batch-telemetry program untouched.
    """
    F = fleet.F
    M = fleet.arrival_amax.shape[1]
    keys = jax.random.split(key, F)
    streaming = split_telemetry(telemetry)[1] is not None
    lanes = jnp.arange(F, dtype=jnp.int32) if streaming else None

    def one(pe, pc, Pe, Pc, ctab, amax, k, graph, err, faults, dl,
            lane):
        spec = NetworkSpec(pe=pe, pc=pc, Pe=Pe, Pc=Pc)
        # TableCarbonSource traces fine with a batched ctab; its .table
        # attribute is also how simulate() hands each lane's slab to
        # table-backed forecasters.
        carbon_source = TableCarbonSource(table=ctab)

        def arrival_source(t, kk):
            u = jax.random.uniform(jax.random.fold_in(kk, t), (M,),
                                   dtype=jnp.float32)
            return jnp.floor(u * (amax + 1.0))

        return simulate(
            policy, spec, carbon_source, arrival_source, T, k,
            forecaster=forecaster, graph=graph, error_params=err,
            record=record, faults=faults, telemetry=telemetry,
            stream_lane=lane, deadlines=dl,
        )

    err = (
        (fleet.err_bias, fleet.err_noise)
        if fleet.err_bias is not None else None
    )
    return jax.vmap(
        one,
        in_axes=(0, 0, 0, 0, 0, 0, 0,
                 0 if fleet.graph is not None else None,
                 0 if err is not None else None,
                 0 if fleet.faults is not None else None,
                 0 if fleet.deadlines is not None else None,
                 0 if streaming else None),
    )(
        fleet.spec.pe, fleet.spec.pc, fleet.spec.Pe, fleet.spec.Pc,
        fleet.carbon, fleet.arrival_amax, keys, fleet.graph, err,
        fleet.faults, fleet.deadlines, lanes,
    )


def mean_rate_stability_metric(result: SimResult) -> Array:
    """E[Q(T)]/T proxy for (10)-(11): total terminal backlog over horizon.
    A mean-rate-stable system drives this toward 0 as T grows."""
    T = result.emissions.shape[0]
    return result.final_backlog / T
