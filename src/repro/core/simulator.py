"""Queueing-network simulator (paper §V numerical analysis).

A single `lax.scan` over time slots: observe carbon intensity + arrivals,
act with the policy, account emissions (eq. 5), step the dynamics
(eqs. 7-8). Fully jittable; `simulate_vsweep` vmaps the whole simulation
over a vector of V values (beyond-paper: the paper's Figs. 2/4 tradeoff
curve computed in one compiled call).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.queueing import (
    Action,
    NetworkSpec,
    NetworkState,
    emissions,
    init_state,
    step,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class UniformArrivals:
    """a_m(t) ~ U{0..amax} i.i.d. (paper §V uses amax=400)."""

    M: int
    amax: int = 400

    def __call__(self, t: Array, key: Array) -> Array:
        k = jax.random.fold_in(key, t)
        return jax.random.randint(k, (self.M,), 0, self.amax + 1).astype(
            jnp.float32
        )

    @property
    def a_max(self) -> float:
        return float(self.amax)


@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """a_m(t) ~ Poisson(rate_m), clipped at `clip` to keep a_m bounded
    (Lemma 1 requires bounded arrivals)."""

    rates: tuple
    clip: int = 2000

    def __call__(self, t: Array, key: Array) -> Array:
        k = jax.random.fold_in(key, t)
        lam = jnp.asarray(self.rates, jnp.float32)
        return jnp.minimum(
            jax.random.poisson(k, lam).astype(jnp.float32), float(self.clip)
        )

    @property
    def a_max(self) -> float:
        return float(self.clip)


class SimResult(NamedTuple):
    emissions: Array      # [T] per-slot carbon emissions C(t)
    cum_emissions: Array  # [T] cumulative sum
    Qe: Array             # [T, M] edge queue trajectory (post-step)
    Qc: Array             # [T, M, N] cloud queue trajectory (post-step)
    dispatched: Array     # [T] total tasks dispatched
    processed: Array      # [T] total tasks processed
    energy_edge: Array    # [T] edge energy spent
    energy_cloud: Array   # [T, N] cloud energy spent

    @property
    def final_backlog(self) -> Array:
        return self.Qe[-1].sum() + self.Qc[-1].sum()


def simulate(
    policy: Callable,
    spec: NetworkSpec,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
    state0: NetworkState | None = None,
) -> SimResult:
    """Runs the network for T slots under `policy`."""
    pe, pc, _, _ = spec.as_arrays()
    if state0 is None:
        state0 = init_state(spec.M, spec.N)
    k_carbon, k_arrive, k_policy = jax.random.split(key, 3)

    def body(state, t):
        Ce, Cc = carbon_source(t, k_carbon)
        a = arrival_source(t, k_arrive)
        act: Action = policy(
            state, spec, Ce, Cc, a, jax.random.fold_in(k_policy, t)
        )
        C_t = emissions(spec, act, Ce, Cc)
        nxt = step(state, act, a)
        out = (
            C_t,
            nxt.Qe,
            nxt.Qc,
            jnp.sum(act.d),
            jnp.sum(act.w),
            jnp.sum(act.d * pe[:, None]),
            jnp.sum(act.w * pc, axis=0),
        )
        return nxt, out

    _, (C, Qe, Qc, disp, proc, ee, ec) = jax.lax.scan(
        body, state0, jnp.arange(T)
    )
    return SimResult(
        emissions=C,
        cum_emissions=jnp.cumsum(C),
        Qe=Qe,
        Qc=Qc,
        dispatched=disp,
        processed=proc,
        energy_edge=ee,
        energy_cloud=ec,
    )


def simulate_vsweep(
    make_policy: Callable[[Array], Callable],
    Vs: Array,
    spec: NetworkSpec,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
) -> SimResult:
    """vmaps the full simulation over a vector of V values.

    `make_policy(V)` must build a policy whose only V-dependence flows
    through traced arithmetic (CarbonIntensityPolicy qualifies).
    """

    def one(V):
        return simulate(
            make_policy(V), spec, carbon_source, arrival_source, T, key
        )

    return jax.vmap(one)(jnp.asarray(Vs, jnp.float32))


def mean_rate_stability_metric(result: SimResult) -> Array:
    """E[Q(T)]/T proxy for (10)-(11): total terminal backlog over horizon.
    A mean-rate-stable system drives this toward 0 as T grows."""
    T = result.emissions.shape[0]
    return result.final_backlog / T
