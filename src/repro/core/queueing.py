"""Virtual queueing network model (paper §III).

State is a pytree of two integer arrays:
  Qe  [M]    -- edge queue m: type-m tasks waiting at the edge server
  Qc  [M,N]  -- cloud queue (m,n): type-m tasks waiting at cloud n

An *action* is (d, w):
  d  [M,N]   -- number of type-m tasks dispatched edge -> cloud n (eq. 1)
  w  [M,N]   -- number of type-m tasks processed at cloud n       (eq. 2)

Dynamics are eqs. (7)-(8) of the paper. Everything here is pure JAX so the
whole network simulates under jax.lax.scan and vmaps over policy
hyper-parameters (e.g. V sweeps).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Queue lengths are kept in float32 on purpose: counts are integral by
# construction (all updates add/subtract integers) but float32 keeps the
# whole simulator in one dtype for TPU-friendly vectorization; exactness
# holds up to 2**24 which is far beyond any stable queue length here.
DTYPE = jnp.float32


class NetworkState(NamedTuple):
    """Virtual queueing network state at one time slot."""

    Qe: Array  # [M]   edge queues
    Qc: Array  # [M,N] cloud queues

    @property
    def M(self) -> int:
        return self.Qe.shape[-1]

    @property
    def N(self) -> int:
        return self.Qc.shape[-1]


class Action(NamedTuple):
    """A scheduling action for one time slot (d, w >= 0 integers)."""

    d: Array  # [M,N] dispatch counts
    w: Array  # [M,N] processing counts


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """Static problem data (paper §II).

    Attributes:
      pe:  [M]   energy for the edge to send one type-m task (kWh)
      pc:  [M,N] energy for cloud n to process one type-m task (kWh)
      Pe:  scalar edge energy budget per slot (kWh)
      Pc:  [N]   per-cloud energy budget per slot (kWh)
    """

    pe: Array
    pc: Array
    Pe: float
    Pc: Array

    @property
    def M(self) -> int:
        return self.pc.shape[0]

    @property
    def N(self) -> int:
        return self.pc.shape[1]

    def as_arrays(self):
        return (
            jnp.asarray(self.pe, DTYPE),
            jnp.asarray(self.pc, DTYPE),
            jnp.asarray(self.Pe, DTYPE),
            jnp.asarray(self.Pc, DTYPE),
        )


def init_state(M: int, N: int, dtype=DTYPE) -> NetworkState:
    return NetworkState(Qe=jnp.zeros((M,), dtype), Qc=jnp.zeros((M, N), dtype))


def edge_energy(spec_pe: Array, d: Array) -> Array:
    """Total edge energy of a dispatch action (eq. 1)."""
    return jnp.sum(d * spec_pe[:, None])


def cloud_energy(spec_pc: Array, w: Array) -> Array:
    """Per-cloud energy of a processing action (eq. 2). Returns [N]."""
    return jnp.sum(w * spec_pc, axis=0)


def emissions(spec: NetworkSpec, action: Action, Ce: Array, Cc: Array) -> Array:
    """Carbon emissions C(t) of an action (eq. 5).

    Ce: scalar edge carbon intensity; Cc: [N] cloud carbon intensities.
    """
    pe, pc, _, _ = spec.as_arrays()
    return Ce * edge_energy(pe, action.d) + jnp.sum(
        Cc * cloud_energy(pc, action.w)
    )


def is_feasible(spec: NetworkSpec, action: Action, atol: float = 1e-3) -> Array:
    """Checks energy constraints (3)-(4) and integrality/non-negativity."""
    pe, pc, Pe, Pc = spec.as_arrays()
    ok_e = edge_energy(pe, action.d) <= Pe + atol
    ok_c = jnp.all(cloud_energy(pc, action.w) <= Pc + atol)
    ok_nonneg = jnp.all(action.d >= 0) & jnp.all(action.w >= 0)
    ok_int = jnp.all(action.d == jnp.round(action.d)) & jnp.all(
        action.w == jnp.round(action.w)
    )
    return ok_e & ok_c & ok_nonneg & ok_int


def step(state: NetworkState, action: Action, arrivals: Array) -> NetworkState:
    """One slot of queue dynamics, eqs. (7)-(8).

    Note the paper's order: departures are bounded by the *current* queue
    via max(.,0); arrivals land after service. d may exceed Qe in which
    case only Qe tasks actually move, yet the full d lands in Qc -- the
    paper's virtual-queue semantics (eq. 8 adds d[m,n] verbatim). Policies
    in this repo never overshoot (they clip to queue lengths), but the
    dynamics stay faithful to the equations.
    """
    d_sum = jnp.sum(action.d, axis=1)  # [M]
    Qe = jnp.maximum(state.Qe - d_sum, 0.0) + arrivals
    Qc = jnp.maximum(state.Qc - action.w, 0.0) + action.d
    return NetworkState(Qe=Qe, Qc=Qc)


def lyapunov(state: NetworkState) -> Array:
    """L(t) = 1/2 (sum Qe^2 + sum Qc^2), eq. (15)."""
    return 0.5 * (jnp.sum(state.Qe**2) + jnp.sum(state.Qc**2))


def drift_bound_B(spec: NetworkSpec, a_max: Array) -> Array:
    """A constant B satisfying eq. (18) for all feasible actions.

    From (18): 2B >= sum a_m^2 + sum (sum_n d)^2 + sum d^2 + sum w^2.
    Feasibility bounds each term: sum_n d[m,:] <= Pe/pe[m] (all budget on
    type m), d[m,n] <= Pe/pe[m], w[m,n] <= Pc[n]/pc[m,n]. We use those
    worst cases; tighter bounds only shrink the B/V gap of Theorem 1.
    """
    pe, pc, Pe, Pc = spec.as_arrays()
    a_max = jnp.asarray(a_max, DTYPE)
    d_row_max = Pe / pe  # [M]
    w_max = Pc[None, :] / pc  # [M,N]
    two_B = (
        jnp.sum(a_max**2)
        + jnp.sum(d_row_max**2)  # (sum_n d)^2 worst case
        + jnp.sum(d_row_max**2)  # sum_n d^2 <= (sum_n d)^2
        + jnp.sum(w_max**2)
    )
    return 0.5 * two_B
