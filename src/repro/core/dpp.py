"""Drift-plus-penalty machinery (paper §IV.A, Lemma 1).

Per-slot surrogate coefficients:

  b[m,n] = V*Ce*pe[m]     + Qc[m,n] - Qe[m]   (dispatch coefficient)
  c[m,n] = V*Cc[n]*pc[m,n] - Qc[m,n]          (processing coefficient)

Minimizing (19) == min sum b*d + sum c*w subject to the energy knapsacks
(12)-(14). These helpers are shared by the policies, the exact-knapsack
oracle and the Lemma-1 property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.queueing import Action, NetworkSpec, NetworkState, emissions, lyapunov, step

Array = jax.Array


def dispatch_scores(
    state: NetworkState, spec_pe: Array, Ce: Array, V: Array
) -> Array:
    """b[m,n] for all (m,n). spec_pe: [M]; Ce scalar."""
    return V * Ce * spec_pe[:, None] + state.Qc - state.Qe[:, None]


def processing_scores(
    state: NetworkState, spec_pc: Array, Cc: Array, V: Array
) -> Array:
    """c[m,n] for all (m,n). spec_pc: [M,N]; Cc: [N]."""
    return V * Cc[None, :] * spec_pc - state.Qc


def surrogate_value(
    state: NetworkState,
    spec: NetworkSpec,
    action: Action,
    Ce: Array,
    Cc: Array,
    V: Array,
) -> Array:
    """Objective (19) evaluated at an action."""
    pe, pc, _, _ = spec.as_arrays()
    b = dispatch_scores(state, pe, Ce, V)
    c = processing_scores(state, pc, Cc, V)
    return jnp.sum(b * action.d) + jnp.sum(c * action.w)


def drift_plus_penalty(
    state: NetworkState,
    spec: NetworkSpec,
    action: Action,
    arrivals: Array,
    Ce: Array,
    Cc: Array,
    V: Array,
) -> Array:
    """Exact Delta(t) + V*C(t) for one realized transition (LHS of (17))."""
    nxt = step(state, action, arrivals)
    return (lyapunov(nxt) - lyapunov(state)) + V * emissions(
        spec, action, Ce, Cc
    )


def lemma1_rhs(
    state: NetworkState,
    spec: NetworkSpec,
    action: Action,
    arrivals: Array,
    Ce: Array,
    Cc: Array,
    V: Array,
    B: Array,
) -> Array:
    """RHS of the Lemma-1 bound (17)."""
    pe, pc, _, _ = spec.as_arrays()
    b = dispatch_scores(state, pe, Ce, V)
    c = processing_scores(state, pc, Cc, V)
    return (
        B
        + jnp.sum(state.Qe * arrivals)
        + jnp.sum(b * action.d)
        + jnp.sum(c * action.w)
    )
