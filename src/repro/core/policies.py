"""Scheduling policies.

* CarbonIntensityPolicy -- the paper's Algorithm 1 (drift-plus-penalty
  greedy). Faithful semantics, expressed through the chunked top_k
  greedy fill so it jits / vmaps / scans at any M.
* QueueLengthPolicy -- the paper's baseline: longest edge queue -> shortest
  cloud queue; clouds always process their longest queues; carbon-blind.
* ExactDPPPolicy -- beyond-paper: solves the per-slot surrogate (19)
  exactly with the unbounded-knapsack DP (small instances; used to
  measure the greedy's optimality gap).
* RandomPolicy -- feasible random actions (stress/property tests).

All policies share the signature:
    policy(state, spec, Ce, Cc, arrivals, key) -> Action
`arrivals` is observed *before* acting (Algorithm 1 line "Observe ...
a_m(t)"): the paper's queue update (7) applies d to the pre-arrival queue;
policies only clip d by the current Qe, matching the pseudocode.

Every policy also accepts a `fault_view=` kwarg (a repro.faults
FaultView, passed by the faulted simulators) and deliberately ignores
it: base policies model the fair-weather scheduler, and all graceful
degradation lives in repro.faults.guard.StalenessGuardPolicy. The same
convention covers `deadline_view=` (a repro.deadlines DeadlineView,
passed by deadline-threaded simulators): base policies ignore it, and
urgency/deferral behavior lives in repro.deadlines.policy.

Notes vs. the paper's pseudocode (documented in DESIGN.md):
  * The edge branch of Algorithm 1 prints `P <- P - floor(P/pe)*pe` while
    the cloud branch subtracts the *scheduled* energy `w*pc`. We treat the
    edge line as a typo (it would burn budget that was never used when
    Qe < floor(P/pe)) and subtract d*pe. Set `literal_edge_budget=True`
    to reproduce the printed text exactly.
  * `stop_at_first_unfit=True` reproduces the pseudocode's `break` when
    the current type no longer fits the remaining budget. The improved
    variant (False) keeps scanning cheaper types -- a strictly better
    knapsack fill (see DESIGN.md §Perf-policy).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dpp
from repro.core.queueing import Action, NetworkSpec, NetworkState
from repro.telemetry.profile import phase

Array = jax.Array


def greedy_fill(
    scores: Array,       # [M] or [B, M] per-item score (negative == take)
    unit_energy: Array,  # [M] or [B, M] energy per item
    max_items: Array,    # [M] or [B, M] cap per item (queue lengths)
    budget: Array,       # scalar or [B] energy budget per lane
    *,
    stop_at_first_unfit: bool = True,
    literal_edge_budget: bool = False,
    sort_key: Array | None = None,
    chunk: int = 64,
) -> Array:
    """The repo's one greedy knapsack fill (Algorithm 1, both halves).

    Semantics (per lane, items visited in increasing `sort_key` order,
    ties broken by index -- `sort_key` defaults to scores/unit_energy):
      fits = floor(P / e); take min(cap, fits) of every item whose score
      is negative, decrementing P by take*e. `stop_at_first_unfit`
      reproduces the pseudocode's `break` at the first fits == 0;
      `literal_edge_budget` reproduces the printed edge line verbatim
      (P -= fits*e, always stopping at the first unfit -- the variant
      ignores `stop_at_first_unfit`, like the pseudocode it mirrors).

    Implementation (§Perf-policy): only items with score < 0 can ever
    take or stop the walk before the takes end -- with the default
    ratio key they sort strictly before every non-negative item, so the
    walk over non-negative items is a no-op tail. Each while_loop trip
    pulls the `chunk` cheapest unprocessed negative-score items with
    lax.top_k (ties resolve to the lowest index == the stable order)
    and walks them with a lax.scan whose body is the sequential
    reference op-for-op, so counts are bit-identical to a full
    sequential pass by construction. The loop exits when a lane stops,
    runs out of negative items, or P drops below the cheapest remaining
    energy (nothing downstream can fit). One trip almost always
    suffices: taking `chunk` items costs >= chunk * min_e energy.

    Batched: stack lanes on a leading axis ([B, M] inputs, [B] budget)
    and every trip issues ONE top_k / ONE scan for all lanes -- that is
    how the policies fill the edge row and all N clouds per slot in a
    single call. Callers passing `sort_key` must keep the contract that
    negative-score items sort before non-negative ones (any key does
    when negative items get negative keys, like -queue-length).

    Caps are treated as integer-valued (queue lengths); the budget walk
    takes cap items whenever floor(P/e) >= cap.
    """
    # The phase scope is profiler metadata only (repro.telemetry
    # §profiling): it labels the fill ops in xprof/Perfetto traces and
    # never changes the computation.
    with phase("greedy_fill"):
        return _greedy_fill(
            scores, unit_energy, max_items, budget,
            stop_at_first_unfit=stop_at_first_unfit,
            literal_edge_budget=literal_edge_budget,
            sort_key=sort_key, chunk=chunk,
        )


def _greedy_fill(
    scores, unit_energy, max_items, budget, *,
    stop_at_first_unfit, literal_edge_budget, sort_key, chunk,
):
    scores = jnp.asarray(scores)
    single = scores.ndim == 1
    if single:
        scores = scores[None]
        unit_energy = jnp.asarray(unit_energy)[None]
        max_items = jnp.asarray(max_items)[None]
        budget = jnp.reshape(jnp.asarray(budget), (1,))
        if sort_key is not None:
            sort_key = jnp.asarray(sort_key)[None]
    B, M = scores.shape
    if int(chunk) < 1:
        raise ValueError(
            f"chunk={chunk!r} must be >= 1 (a zero-size chunk would "
            "loop forever processing nothing)"
        )
    k = min(int(chunk), M)
    stops = stop_at_first_unfit or literal_edge_budget

    key = sort_key if sort_key is not None else scores / unit_energy
    mkey0 = jnp.where(scores < 0, key, jnp.inf)
    P0 = jnp.broadcast_to(jnp.asarray(budget, jnp.float32), (B,))

    def active(P, stopped, mkey):
        alive = jnp.isfinite(mkey)
        min_e = jnp.min(
            jnp.where(alive, unit_energy, jnp.inf), axis=-1
        )
        return (~stopped) & jnp.any(alive, axis=-1) & (P >= min_e)

    def step(carry, item):
        P, stopped = carry
        e_j, s_j, cap_j, live_j = item
        fits = jnp.floor(P / e_j)
        live = live_j & (~stopped)
        can = live & (fits > 0.0) & (s_j < 0)
        t_j = jnp.where(can, jnp.minimum(cap_j, fits), 0.0)
        if literal_edge_budget:
            P = jnp.where(can, P - fits * e_j, P)
        else:
            P = P - t_j * e_j  # t_j == 0 is an exact no-op
        if stops:
            stopped = stopped | (live & (fits <= 0.0))
        return (P, stopped), t_j

    def walk_chunk(P, stopped, mkey, gate):
        neg, idx = jax.lax.top_k(-mkey, k)  # k smallest keys, stable
        valid = jnp.isfinite(neg) & gate
        e_s = jnp.take_along_axis(unit_energy, idx, axis=-1)
        s_s = jnp.take_along_axis(scores, idx, axis=-1)
        cap_s = jnp.take_along_axis(max_items, idx, axis=-1)
        (P, stopped), takes = jax.lax.scan(
            step, (P, stopped), (e_s.T, s_s.T, cap_s.T, valid.T)
        )
        return P, stopped, idx, takes.T

    # Per-lane scatters flattened into ONE row-major scatter on [B*M]:
    # bit-identical to the per-row vmap formulation (indices stay
    # unique), one scatter instead of a batched one, and -- because an
    # unbatched scatter is all checkify's OOB rule can instrument --
    # the only formulation `analysis.sanitize` can lift with
    # index_checks enabled.
    def _rows(i):
        return (i + M * jnp.arange(B, dtype=i.dtype)[:, None]).ravel()

    def _scatter_add(t, i, v):
        return t.ravel().at[_rows(i)].add(v.ravel()).reshape(B, M)

    stopped0 = jnp.zeros((B,), bool)
    if k == M:
        # One trip provably covers every item: skip the while_loop and
        # its exit bookkeeping entirely (the common small-M / fleet-lane
        # case; per-slot cost matches the old argsort+scan fill).
        _, _, idx, takes = walk_chunk(P0, stopped0, mkey0, True)
        counts = _scatter_add(jnp.zeros_like(scores), idx, takes)
        return counts[0] if single else counts

    def trip(carry):
        P, stopped, take, mkey, act = carry
        P, stopped, idx, takes = walk_chunk(P, stopped, mkey, act[:, None])
        take = _scatter_add(take, idx, takes)
        done = mkey.ravel().at[_rows(idx)].set(jnp.inf).reshape(B, M)
        mkey = jnp.where(act[:, None], done, mkey)
        return P, stopped, take, mkey, active(P, stopped, mkey)

    carry = jax.lax.while_loop(
        lambda c: jnp.any(c[4]),
        trip,
        (P0, stopped0, jnp.zeros_like(scores), mkey0,
         active(P0, stopped0, mkey0)),
    )
    counts = carry[2]
    return counts[0] if single else counts


@dataclasses.dataclass(frozen=True)
class CarbonIntensityPolicy:
    """Paper Algorithm 1: carbon-intensity based drift-plus-penalty greedy.

    The edge dispatch row and all N cloud processing rows go through ONE
    stacked `greedy_fill` call per slot (chunked top_k engine, see
    DESIGN.md §Perf-policy); `fill_chunk` sizes the per-trip top_k.

    score_backend selects how the per-slot score pass (n1, b, c) is
    computed:
      * "reference" -- plain jnp (default; works everywhere, vmaps).
      * "pallas"    -- the fused kernels.carbon_score.carbon_scores
        kernel: one HBM sweep of Qc/pc produces the c-matrix and the
        per-row (min, argmin) reduction. Falls back to interpret mode
        off-TPU (score_interpret=None -> auto) and pads internally, so
        any M/N works. Under jit both backends produce bit-identical
        scores, hence bit-identical actions (tests/test_score_backend).
    """

    V: float = 0.05
    stop_at_first_unfit: bool = True
    literal_edge_budget: bool = False
    fill_chunk: int = 64
    score_backend: str = "reference"
    score_block_m: int = 256
    score_block_n: int = 256
    score_interpret: bool | None = None

    def _fill_all(self, b, c, pe, pc, Qe, Qc, Pe, Pc):
        """Edge dispatch + N cloud fills as one stacked [N+1, M] greedy
        fill (shared with NetworkAwareDPPPolicy, whose dispatch scores
        differ but whose fill semantics are exactly Algorithm 1's).
        Returns (d_counts [M], w [M, N])."""
        if self.literal_edge_budget:
            # The literal pseudocode variant only exists for the edge
            # branch; clouds keep the corrected budget accounting.
            d_counts = greedy_fill(
                b, pe, Qe, Pe,
                literal_edge_budget=True, chunk=self.fill_chunk,
            )
            w = greedy_fill(
                c.T, pc.T, Qc.T, Pc,
                stop_at_first_unfit=self.stop_at_first_unfit,
                chunk=self.fill_chunk,
            ).T
            return d_counts, w
        counts = greedy_fill(
            jnp.concatenate([b[None, :], c.T], axis=0),
            jnp.concatenate([pe[None, :], pc.T], axis=0),
            jnp.concatenate([Qe[None, :], Qc.T], axis=0),
            jnp.concatenate([jnp.reshape(Pe, (1,)), Pc], axis=0),
            stop_at_first_unfit=self.stop_at_first_unfit,
            chunk=self.fill_chunk,
        )
        return counts[0], counts[1:].T

    def _scores(self, state, pe, pc, Ce, Cc, V):
        """Score pass: (c [M,N], n1 [M], b [M]) via the selected backend.
        The phase scope labels it in profiler traces (metadata only)."""
        with phase("policy_score"):
            if self.score_backend == "pallas":
                from repro.kernels import ops

                # The kernel contract takes pre-scaled intensities:
                # V*Cc for the c-matrix and V*Ce for the b-vector (same
                # op order as the reference, so results agree bitwise
                # under jit).
                return ops.carbon_scores(
                    state.Qc, pc, state.Qe, pe, V * Cc, V * Ce,
                    block_m=self.score_block_m,
                    block_n=self.score_block_n,
                    interpret=self.score_interpret,
                )
            if self.score_backend != "reference":
                raise ValueError(
                    f"unknown score_backend {self.score_backend!r}"
                )
            from repro.kernels import ref

            return ref.carbon_scores_ref(
                state.Qc, pc, state.Qe, pe, V * Cc, V * Ce
            )

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del arrivals, key, fault_view, deadline_view
        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)

        c, n1, b = self._scores(state, pe, pc, Ce, Cc, V)
        d_counts, w = self._fill_all(
            b, c, pe, pc, state.Qe, state.Qc, Pe, Pc
        )
        d = jnp.zeros_like(state.Qc).at[jnp.arange(spec.M), n1].set(d_counts)
        return Action(d=d, w=w)


@dataclasses.dataclass(frozen=True)
class LookaheadDPPPolicy(CarbonIntensityPolicy):
    """Receding-horizon drift-plus-penalty (beyond-paper, forecast
    subsystem). Plans against an [H, N+1] intensity forecast and
    executes only the first slot: the myopic scores are recomputed with
    *deferral-penalized* intensities

        C_eff = C_now + defer_weight * max(0, C_now - Cmin)
        Cmin  = min_h forecast[h] / discount**h         (h = 0..H-1)

    so a trough h slots ahead must beat the present by 1/discount**h
    before it raises the bar for acting now -- the discounting absorbs
    forecast-error growth and the queue-holding cost of waiting. Row 0
    of the forecast is overwritten with the observed (Ce, Cc), hence
    H=1 gives Cmin = C_now, zero penalty, and *bit-identical* actions
    to CarbonIntensityPolicy on either score backend (the modified
    intensities feed the identical score/fill pipeline). See DESIGN.md
    §Receding-horizon lookahead.

    With no forecast supplied (forecast=None) the policy degrades to
    the myopic parent -- simulate() only threads forecasts when a
    forecaster is given.
    """

    H: int = 8
    discount: float = 0.98
    defer_weight: float = 2.0

    def effective_intensities(
        self, Ce: Array, Cc: Array, forecast: Array | None
    ) -> Tuple[Array, Array]:
        if forecast is None or self.H <= 0:
            return Ce, Cc
        if forecast.shape[0] < self.H:
            raise ValueError(
                f"forecast covers {forecast.shape[0]} slots but the policy "
                f"plans over H={self.H}: configure the forecaster with "
                f"H >= {self.H} (silently planning short would mislabel "
                "every lookahead result)"
            )
        f = forecast[: self.H].astype(jnp.float32)
        f = f.at[0].set(jnp.concatenate([Ce[None], Cc]))
        g = jnp.asarray(self.discount, jnp.float32) ** jnp.arange(
            f.shape[0], dtype=jnp.float32
        )
        cmin = jnp.min(f / g[:, None], axis=0)  # [N+1]
        w = jnp.asarray(self.defer_weight, jnp.float32)
        Ce_eff = Ce + w * jnp.maximum(0.0, Ce - cmin[0])
        Cc_eff = Cc + w * jnp.maximum(0.0, Cc - cmin[1:])
        return Ce_eff, Cc_eff

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        forecast: Array | None = None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del fault_view, deadline_view
        Ce_eff, Cc_eff = self.effective_intensities(Ce, Cc, forecast)
        return super().__call__(state, spec, Ce_eff, Cc_eff, arrivals, key)


@dataclasses.dataclass(frozen=True)
class QueueLengthPolicy:
    """Paper §V baseline: queue-length based, carbon-blind.

    Edge: longest edge queues dispatch first, each type to its shortest
    cloud queue, as many as energy allows. Clouds: longest cloud queues
    process first, as many as energy allows. Same stacked greedy_fill
    engine as Algorithm 1, ordered by -queue-length (sort_key) instead
    of score-per-energy, never stopping at an unfit type.
    """

    fill_chunk: int = 64

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del Ce, Cc, arrivals, key, fault_view, deadline_view
        pe, pc, Pe, Pc = spec.as_arrays()
        n1 = jnp.argmin(state.Qc, axis=1)

        # Longest-queue-first: order by -Q (only types with waiting
        # tasks), take as many as the remaining energy allows.
        scores = jnp.concatenate(
            [
                jnp.where(state.Qe > 0, -state.Qe, 1.0)[None, :],
                jnp.where(state.Qc > 0, -state.Qc, 1.0).T,
            ],
            axis=0,
        )
        counts = greedy_fill(
            scores,
            jnp.concatenate([pe[None, :], pc.T], axis=0),
            jnp.concatenate([state.Qe[None, :], state.Qc.T], axis=0),
            jnp.concatenate([jnp.reshape(Pe, (1,)), Pc], axis=0),
            stop_at_first_unfit=False,
            sort_key=scores,
            chunk=self.fill_chunk,
        )
        d = jnp.zeros_like(state.Qc).at[jnp.arange(spec.M), n1].set(counts[0])
        return Action(d=d, w=counts[1:].T)


@dataclasses.dataclass(frozen=True)
class RandomPolicy:
    """Feasible uniformly-random actions (tests / stress)."""

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del Ce, Cc, arrivals, fault_view, deadline_view
        pe, pc, Pe, Pc = spec.as_arrays()
        kd, kw = jax.random.split(key)
        # Random fractions of per-type feasible maxima, scaled to respect
        # the shared budget by dividing across types.
        M, N = spec.M, spec.N
        fd = jax.random.uniform(kd, (M, N), dtype=jnp.float32)
        cap_d = jnp.minimum(
            state.Qe[:, None] / N, (Pe / (M * N)) / pe[:, None]
        )
        d = jnp.floor(fd * jnp.maximum(cap_d, 0.0))
        fw = jax.random.uniform(kw, (M, N), dtype=jnp.float32)
        cap_w = jnp.minimum(state.Qc, (Pc[None, :] / M) / pc)
        w = jnp.floor(fw * jnp.maximum(cap_w, 0.0))
        return Action(d=d, w=w)


@dataclasses.dataclass(frozen=True)
class ExactDPPPolicy:
    """Beyond-paper: exact per-slot minimizer of (19) via unbounded-
    knapsack DP over a discretized energy grid. Exponential-free but
    O(M * budget/gcd) -- use on small instances to measure the greedy gap.
    """

    V: float = 0.05
    grid: int = 512  # energy discretization cells per knapsack

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del arrivals, key, fault_view, deadline_view
        from repro.core.knapsack import bounded_knapsack_min

        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)

        n1 = jnp.argmin(state.Qc, axis=1)
        Qc_n1 = jnp.take_along_axis(state.Qc, n1[:, None], axis=1)[:, 0]
        b = V * Ce * pe + Qc_n1 - state.Qe
        d_counts = bounded_knapsack_min(b, pe, state.Qe, Pe, self.grid)
        d = jnp.zeros_like(state.Qc).at[jnp.arange(spec.M), n1].set(d_counts)

        c = dpp.processing_scores(state, pc, Cc, V)
        w = jax.vmap(
            lambda c_n, pc_n, Qc_n, Pc_n: bounded_knapsack_min(
                c_n, pc_n, Qc_n, Pc_n, self.grid
            ),
            in_axes=(1, 1, 1, 0),
            out_axes=1,
        )(c, pc, state.Qc, Pc)
        return Action(d=d, w=w)


def literal_algorithm1(
    state, spec, Ce, Cc, V,
    stop_at_first_unfit=True, literal_edge_budget=False,
):
    """Pure-Python transcription of Algorithm 1 (numpy, data-dependent
    control flow). Oracle for tests: the vectorized policy must match.
    `literal_edge_budget=True` reproduces the printed edge line
    (`P <- P - floor(P/pe)*pe`, always breaking at the first unfit),
    mirroring CarbonIntensityPolicy's flag of the same name."""
    import numpy as np

    pe = np.asarray(spec.pe, np.float64)
    pc = np.asarray(spec.pc, np.float64)
    Qe = np.asarray(state.Qe, np.float64).copy()
    Qc = np.asarray(state.Qc, np.float64).copy()
    Ce = float(Ce)
    Cc = np.asarray(Cc, np.float64)
    M, N = pc.shape
    d = np.zeros((M, N))
    w = np.zeros((M, N))

    n1 = np.argmin(Qc, axis=1)
    b = V * Ce * pe + Qc[np.arange(M), n1] - Qe
    order = np.argsort(b / pe, kind="stable")
    P = float(spec.Pe)
    for m in order:
        fits = np.floor(P / pe[m])
        if fits <= 0:
            if stop_at_first_unfit or literal_edge_budget:
                break
            continue
        if b[m] < 0:
            take = min(Qe[m], fits)
            d[m, n1[m]] = take
            P -= (fits if literal_edge_budget else take) * pe[m]

    for n in range(N):
        c = V * Cc[n] * pc[:, n] - Qc[:, n]
        order = np.argsort(c / pc[:, n], kind="stable")
        P = float(np.asarray(spec.Pc)[n])
        for m in order:
            fits = np.floor(P / pc[m, n])
            if fits <= 0:
                if stop_at_first_unfit:
                    break
                continue
            if c[m] < 0:
                take = min(Qc[m, n], fits)
                w[m, n] = take
                P -= take * pc[m, n]
    return Action(d=jnp.asarray(d, jnp.float32), w=jnp.asarray(w, jnp.float32))
