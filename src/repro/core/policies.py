"""Scheduling policies.

* CarbonIntensityPolicy -- the paper's Algorithm 1 (drift-plus-penalty
  greedy). Faithful semantics, expressed as a fixed-shape lax.scan over
  sorted task types so it jits / vmaps / scans.
* QueueLengthPolicy -- the paper's baseline: longest edge queue -> shortest
  cloud queue; clouds always process their longest queues; carbon-blind.
* ExactDPPPolicy -- beyond-paper: solves the per-slot surrogate (19)
  exactly with the unbounded-knapsack DP (small instances; used to
  measure the greedy's optimality gap).
* RandomPolicy -- feasible random actions (stress/property tests).

All policies share the signature:
    policy(state, spec, Ce, Cc, arrivals, key) -> Action
`arrivals` is observed *before* acting (Algorithm 1 line "Observe ...
a_m(t)"): the paper's queue update (7) applies d to the pre-arrival queue;
policies only clip d by the current Qe, matching the pseudocode.

Notes vs. the paper's pseudocode (documented in DESIGN.md):
  * The edge branch of Algorithm 1 prints `P <- P - floor(P/pe)*pe` while
    the cloud branch subtracts the *scheduled* energy `w*pc`. We treat the
    edge line as a typo (it would burn budget that was never used when
    Qe < floor(P/pe)) and subtract d*pe. Set `literal_edge_budget=True`
    to reproduce the printed text exactly.
  * `stop_at_first_unfit=True` reproduces the pseudocode's `break` when
    the current type no longer fits the remaining budget. The improved
    variant (False) keeps scanning cheaper types -- a strictly better
    knapsack fill (see DESIGN.md §Perf-policy).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import dpp
from repro.core.queueing import Action, NetworkSpec, NetworkState

Array = jax.Array


def _greedy_fill(
    scores: Array,  # [M] per-unit-of-item score (negative == beneficial)
    unit_energy: Array,  # [M] energy per item
    max_items: Array,  # [M] cap per item (queue lengths)
    budget: Array,  # scalar energy budget
    stop_at_first_unfit: bool,
) -> Array:
    """Greedy knapsack fill used by both halves of Algorithm 1.

    Scans item types in increasing order of scores/unit_energy, taking
    min(max_items, floor(P/energy)) of every type whose score is negative,
    decrementing the remaining budget. Returns the integer counts [M].
    """
    ratio = scores / unit_energy
    order = jnp.argsort(ratio)  # increasing: most beneficial first

    def body(carry, idx):
        P, stopped = carry
        e = unit_energy[idx]
        fits = jnp.floor(P / e)
        can_take = (fits > 0) & (scores[idx] < 0) & (~stopped)
        take = jnp.where(can_take, jnp.minimum(max_items[idx], fits), 0.0)
        P = P - take * e
        if stop_at_first_unfit:
            stopped = stopped | (fits <= 0)
        return (P, stopped), (idx, take)

    (_, _), (idxs, takes) = jax.lax.scan(
        body, (budget.astype(jnp.float32), jnp.asarray(False)), order
    )
    counts = jnp.zeros_like(scores).at[idxs].set(takes)
    return counts


def _greedy_fill_fast(
    scores: Array,
    unit_energy: Array,
    max_items: Array,
    budget: Array,
    window: int = 64,  # kept for API compat; the tail loop is adaptive
) -> Array:
    """O(M log M) vectorized greedy (beyond-paper, §Perf iteration 4).

    Observation: in sorted order, every item before the budget crossing is
    taken at FULL cap (remaining >= cap_i*e_i implies floor(remaining/e_i)
    >= cap_i), so phase 1 is one cumsum; only the short tail after the
    crossing needs the sequential budget recursion. Phase 2 walks that
    tail with a while_loop that exits on the faithful `break` (fits==0)
    or exhaustion -- exact Algorithm-1 output by construction, and under
    vmap the batched trip count is the MAX tail length across lanes
    (typically <10 vs the baseline's full M sequential steps).
    """
    del window
    M = scores.shape[0]
    ratio = scores / unit_energy
    order = jnp.argsort(ratio)
    s = scores[order]
    e = unit_energy[order]
    cap = max_items[order]

    want = jnp.where(s < 0, cap, 0.0)
    cost = want * e
    prefix = jnp.cumsum(cost) - cost  # energy spent BEFORE item i if all full
    full = prefix + cost <= budget
    take_full = jnp.where(full, want, 0.0)

    all_full = jnp.all(full)
    start = jnp.where(all_full, M, jnp.argmax(~full)).astype(jnp.int32)
    # budget remaining when the sequential greedy reaches `start`: every
    # item before it is provably taken at full want.
    P0 = budget.astype(jnp.float32) - jnp.where(
        all_full, jnp.sum(cost), prefix[jnp.clip(start, 0, M - 1)]
    )
    # suffix-min energy among still-takeable items: once P drops below it
    # no later item takes anything, so exiting is output-equivalent even
    # though the paper's loop would keep walking.
    e_neg = jnp.where(s < 0, e, jnp.inf)
    suff_min_e = jax.lax.cummin(e_neg[::-1])[::-1]
    suff_min_e = jnp.concatenate([suff_min_e, jnp.array([jnp.inf])])

    # Phase 2: walk the tail exactly like the reference. Items i>=start
    # that phase 1 marked `full` are still taken at full want (remaining
    # budget is only ever >= phase 1's assumption), so their take is
    # already recorded -- but their energy and the break check still
    # apply in program order.
    def cond(carry):
        P, i, stopped, take = carry
        return (~stopped) & (i < M) & (
            P >= suff_min_e[jnp.clip(i, 0, M)]
        )

    def body(carry):
        P, i, stopped, take = carry
        idx = jnp.clip(i, 0, M - 1)
        fits = jnp.floor(P / e[idx])
        stop_now = fits <= 0  # the paper's break (checked before taking)
        t = jnp.where(
            (~stop_now) & (s[idx] < 0), jnp.minimum(cap[idx], fits), 0.0
        )
        new = jnp.where(full[idx], 0.0, t)  # full items already recorded
        take = take.at[idx].add(jnp.where(stop_now, 0.0, new))
        P = P - jnp.where(stop_now, 0.0, t) * e[idx]
        return (P, i + 1, stop_now, take)

    _, _, _, take_sorted = jax.lax.while_loop(
        cond, body, (P0, start, jnp.asarray(False), take_full)
    )
    return jnp.zeros_like(scores).at[order].set(take_sorted)


def _literal_edge_fill(
    scores: Array, unit_energy: Array, max_items: Array, budget: Array
) -> Array:
    """Edge fill following the printed pseudocode verbatim:
    P <- P - floor(P/pe)*pe even when d was clipped by the queue."""
    ratio = scores / unit_energy
    order = jnp.argsort(ratio)

    def body(carry, idx):
        P, stopped = carry
        e = unit_energy[idx]
        fits = jnp.floor(P / e)
        can_take = (fits > 0) & (scores[idx] < 0) & (~stopped)
        take = jnp.where(can_take, jnp.minimum(max_items[idx], fits), 0.0)
        P = jnp.where(can_take, P - fits * e, P)
        stopped = stopped | (fits <= 0)
        return (P, stopped), (idx, take)

    (_, _), (idxs, takes) = jax.lax.scan(
        body, (budget.astype(jnp.float32), jnp.asarray(False)), order
    )
    return jnp.zeros_like(scores).at[idxs].set(takes)


@dataclasses.dataclass(frozen=True)
class CarbonIntensityPolicy:
    """Paper Algorithm 1: carbon-intensity based drift-plus-penalty greedy.

    fast=True switches the greedy fill to the vectorized cumsum+window
    formulation (identical output, ~25x per-slot latency at M>=2048; see
    DESIGN.md §Perf-policy). Only valid with the faithful
    stop_at_first_unfit semantics.

    score_backend selects how the per-slot score pass (n1, b, c) is
    computed:
      * "reference" -- plain jnp (default; works everywhere, vmaps).
      * "pallas"    -- the fused kernels.carbon_score.carbon_scores
        kernel: one HBM sweep of Qc/pc produces the c-matrix and the
        per-row (min, argmin) reduction. Falls back to interpret mode
        off-TPU (score_interpret=None -> auto) and pads internally, so
        any M/N works. Under jit both backends produce bit-identical
        scores, hence bit-identical actions (tests/test_score_backend).
    """

    V: float = 0.05
    stop_at_first_unfit: bool = True
    literal_edge_budget: bool = False
    fast: bool = False
    fast_window: int = 64
    score_backend: str = "reference"
    score_block_m: int = 256
    score_block_n: int = 256
    score_interpret: bool | None = None

    def _fill(self, scores, energy, caps, budget):
        if self.fast and self.stop_at_first_unfit:
            return _greedy_fill_fast(
                scores, energy, caps, budget, self.fast_window
            )
        return _greedy_fill(
            scores, energy, caps, budget, self.stop_at_first_unfit
        )

    def _scores(self, state, pe, pc, Ce, Cc, V):
        """Score pass: (c [M,N], n1 [M], b [M]) via the selected backend."""
        if self.score_backend == "pallas":
            from repro.kernels import ops

            # The kernel contract takes pre-scaled intensities: V*Cc for
            # the c-matrix and V*Ce for the b-vector (same op order as
            # the reference, so results agree bitwise under jit).
            return ops.carbon_scores(
                state.Qc, pc, state.Qe, pe, V * Cc, V * Ce,
                block_m=self.score_block_m, block_n=self.score_block_n,
                interpret=self.score_interpret,
            )
        if self.score_backend != "reference":
            raise ValueError(
                f"unknown score_backend {self.score_backend!r}"
            )
        from repro.kernels import ref

        return ref.carbon_scores_ref(
            state.Qc, pc, state.Qe, pe, V * Cc, V * Ce
        )

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
    ) -> Action:
        del arrivals, key
        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)

        c, n1, b = self._scores(state, pe, pc, Ce, Cc, V)

        # --- Edge: dispatch each type to its emptiest cloud queue. -------
        if self.literal_edge_budget:
            d_counts = _literal_edge_fill(b, pe, state.Qe, Pe)
        else:
            d_counts = self._fill(b, pe, state.Qe, Pe)
        d = jnp.zeros_like(state.Qc).at[jnp.arange(spec.M), n1].set(d_counts)

        # --- Clouds: process most-backlogged-per-energy types. -----------
        w = self._cloud_fill(c, pc, state.Qc, Pc)
        return Action(d=d, w=w)

    def _cloud_fill(self, c, pc, Qc, Pc):
        """Per-cloud greedy processing fill on the c-score matrix
        (shared with NetworkAwareDPPPolicy, whose dispatch half differs
        but whose processing half is exactly Algorithm 1's)."""

        def per_cloud(c_n, pc_n, Qc_n, Pc_n):
            return self._fill(c_n, pc_n, Qc_n, Pc_n)

        return jax.vmap(per_cloud, in_axes=(1, 1, 1, 0), out_axes=1)(
            c, pc, Qc, Pc
        )


@dataclasses.dataclass(frozen=True)
class LookaheadDPPPolicy(CarbonIntensityPolicy):
    """Receding-horizon drift-plus-penalty (beyond-paper, forecast
    subsystem). Plans against an [H, N+1] intensity forecast and
    executes only the first slot: the myopic scores are recomputed with
    *deferral-penalized* intensities

        C_eff = C_now + defer_weight * max(0, C_now - Cmin)
        Cmin  = min_h forecast[h] / discount**h         (h = 0..H-1)

    so a trough h slots ahead must beat the present by 1/discount**h
    before it raises the bar for acting now -- the discounting absorbs
    forecast-error growth and the queue-holding cost of waiting. Row 0
    of the forecast is overwritten with the observed (Ce, Cc), hence
    H=1 gives Cmin = C_now, zero penalty, and *bit-identical* actions
    to CarbonIntensityPolicy on either score backend (the modified
    intensities feed the identical score/fill pipeline). See DESIGN.md
    §Receding-horizon lookahead.

    With no forecast supplied (forecast=None) the policy degrades to
    the myopic parent -- simulate() only threads forecasts when a
    forecaster is given.
    """

    H: int = 8
    discount: float = 0.98
    defer_weight: float = 2.0

    def effective_intensities(
        self, Ce: Array, Cc: Array, forecast: Array | None
    ) -> Tuple[Array, Array]:
        if forecast is None or self.H <= 0:
            return Ce, Cc
        if forecast.shape[0] < self.H:
            raise ValueError(
                f"forecast covers {forecast.shape[0]} slots but the policy "
                f"plans over H={self.H}: configure the forecaster with "
                f"H >= {self.H} (silently planning short would mislabel "
                "every lookahead result)"
            )
        f = forecast[: self.H].astype(jnp.float32)
        f = f.at[0].set(jnp.concatenate([Ce[None], Cc]))
        g = jnp.asarray(self.discount, jnp.float32) ** jnp.arange(
            f.shape[0], dtype=jnp.float32
        )
        cmin = jnp.min(f / g[:, None], axis=0)  # [N+1]
        w = jnp.asarray(self.defer_weight, jnp.float32)
        Ce_eff = Ce + w * jnp.maximum(0.0, Ce - cmin[0])
        Cc_eff = Cc + w * jnp.maximum(0.0, Cc - cmin[1:])
        return Ce_eff, Cc_eff

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        forecast: Array | None = None,
    ) -> Action:
        Ce_eff, Cc_eff = self.effective_intensities(Ce, Cc, forecast)
        return super().__call__(state, spec, Ce_eff, Cc_eff, arrivals, key)


@dataclasses.dataclass(frozen=True)
class QueueLengthPolicy:
    """Paper §V baseline: queue-length based, carbon-blind.

    Edge: longest edge queues dispatch first, each type to its shortest
    cloud queue, as many as energy allows. Clouds: longest cloud queues
    process first, as many as energy allows.
    """

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
    ) -> Action:
        del Ce, Cc, arrivals, key
        pe, pc, Pe, Pc = spec.as_arrays()
        n1 = jnp.argmin(state.Qc, axis=1)

        # Longest-queue-first: order by -Q (only types with waiting tasks),
        # take as many as the remaining energy allows.
        order_scores = jnp.where(state.Qe > 0, -state.Qe, 1.0)

        def edge_fill(scores, energy, caps, budget):
            order = jnp.argsort(scores)

            def body(P, idx):
                e = energy[idx]
                fits = jnp.floor(P / e)
                take = jnp.where(
                    (scores[idx] < 0) & (fits > 0),
                    jnp.minimum(caps[idx], fits),
                    0.0,
                )
                return P - take * e, (idx, take)

            _, (idxs, takes) = jax.lax.scan(
                body, budget.astype(jnp.float32), order
            )
            return jnp.zeros_like(scores).at[idxs].set(takes)

        d_counts = edge_fill(order_scores, pe, state.Qe, Pe)
        d = jnp.zeros_like(state.Qc).at[jnp.arange(spec.M), n1].set(d_counts)

        def per_cloud(Qc_n, pc_n, Pc_n):
            scores = jnp.where(Qc_n > 0, -Qc_n, 1.0)
            return edge_fill(scores, pc_n, Qc_n, Pc_n)

        w = jax.vmap(per_cloud, in_axes=(1, 1, 0), out_axes=1)(
            state.Qc, pc, Pc
        )
        return Action(d=d, w=w)


@dataclasses.dataclass(frozen=True)
class RandomPolicy:
    """Feasible uniformly-random actions (tests / stress)."""

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array,
    ) -> Action:
        del Ce, Cc, arrivals
        pe, pc, Pe, Pc = spec.as_arrays()
        kd, kw = jax.random.split(key)
        # Random fractions of per-type feasible maxima, scaled to respect
        # the shared budget by dividing across types.
        M, N = spec.M, spec.N
        fd = jax.random.uniform(kd, (M, N))
        cap_d = jnp.minimum(
            state.Qe[:, None] / N, (Pe / (M * N)) / pe[:, None]
        )
        d = jnp.floor(fd * jnp.maximum(cap_d, 0.0))
        fw = jax.random.uniform(kw, (M, N))
        cap_w = jnp.minimum(state.Qc, (Pc[None, :] / M) / pc)
        w = jnp.floor(fw * jnp.maximum(cap_w, 0.0))
        return Action(d=d, w=w)


@dataclasses.dataclass(frozen=True)
class ExactDPPPolicy:
    """Beyond-paper: exact per-slot minimizer of (19) via unbounded-
    knapsack DP over a discretized energy grid. Exponential-free but
    O(M * budget/gcd) -- use on small instances to measure the greedy gap.
    """

    V: float = 0.05
    grid: int = 512  # energy discretization cells per knapsack

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
    ) -> Action:
        del arrivals, key
        from repro.core.knapsack import bounded_knapsack_min

        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)

        n1 = jnp.argmin(state.Qc, axis=1)
        Qc_n1 = jnp.take_along_axis(state.Qc, n1[:, None], axis=1)[:, 0]
        b = V * Ce * pe + Qc_n1 - state.Qe
        d_counts = bounded_knapsack_min(b, pe, state.Qe, Pe, self.grid)
        d = jnp.zeros_like(state.Qc).at[jnp.arange(spec.M), n1].set(d_counts)

        c = dpp.processing_scores(state, pc, Cc, V)
        w = jax.vmap(
            lambda c_n, pc_n, Qc_n, Pc_n: bounded_knapsack_min(
                c_n, pc_n, Qc_n, Pc_n, self.grid
            ),
            in_axes=(1, 1, 1, 0),
            out_axes=1,
        )(c, pc, state.Qc, Pc)
        return Action(d=d, w=w)


def literal_algorithm1(state, spec, Ce, Cc, V, stop_at_first_unfit=True):
    """Pure-Python transcription of Algorithm 1 (numpy, data-dependent
    control flow). Oracle for tests: the vectorized policy must match."""
    import numpy as np

    pe = np.asarray(spec.pe, np.float64)
    pc = np.asarray(spec.pc, np.float64)
    Qe = np.asarray(state.Qe, np.float64).copy()
    Qc = np.asarray(state.Qc, np.float64).copy()
    Ce = float(Ce)
    Cc = np.asarray(Cc, np.float64)
    M, N = pc.shape
    d = np.zeros((M, N))
    w = np.zeros((M, N))

    n1 = np.argmin(Qc, axis=1)
    b = V * Ce * pe + Qc[np.arange(M), n1] - Qe
    order = np.argsort(b / pe, kind="stable")
    P = float(spec.Pe)
    for m in order:
        fits = np.floor(P / pe[m])
        if fits <= 0:
            if stop_at_first_unfit:
                break
            continue
        if b[m] < 0:
            take = min(Qe[m], fits)
            d[m, n1[m]] = take
            P -= take * pe[m]

    for n in range(N):
        c = V * Cc[n] * pc[:, n] - Qc[:, n]
        order = np.argsort(c / pc[:, n], kind="stable")
        P = float(np.asarray(spec.Pc)[n])
        for m in order:
            fits = np.floor(P / pc[m, n])
            if fits <= 0:
                if stop_at_first_unfit:
                    break
                continue
            if c[m] < 0:
                take = min(Qc[m, n], fits)
                w[m, n] = take
                P -= take * pc[m, n]
    return Action(d=jnp.asarray(d, jnp.float32), w=jnp.asarray(w, jnp.float32))
