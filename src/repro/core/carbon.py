"""Carbon-intensity sources (paper §V scenarios).

Two scenarios from the paper plus a drop-in loader for real data:

  * RandomCarbonSource     -- Ce(t), Cc_n(t) ~ U{0..700} i.i.d.   (Fig. 2)
  * UKRegionalTraceSource  -- realistic synthetic stand-in for the
    National Grid ESO regional 30-min traces used in Fig. 3. The real API
    is unreachable offline; this generator reproduces the structure of
    2022 UK regional carbon intensity: a diurnal cycle (demand peaking
    ~18:00), multi-day wind-front excursions, region-specific means
    (Scotland low / South Wales high), and short spikes. A CSV loader with
    the ESO schema (`from_eso_csv`) accepts real exports verbatim.
  * ConstantCarbonSource   -- for unit tests / ablations.

A source is a callable `(t_slot:int32, key) -> (Ce scalar, Cc [N])`, pure
JAX so the simulator scans over it.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RandomCarbonSource:
    """Paper Fig. 2: each intensity i.i.d. uniform over {0..cmax}."""

    N: int
    cmax: int = 700

    def __call__(self, t: Array, key: Array) -> Tuple[Array, Array]:
        ke, kc = jax.random.split(jax.random.fold_in(key, t))
        Ce = jax.random.randint(ke, (), 0, self.cmax + 1).astype(jnp.float32)
        Cc = jax.random.randint(kc, (self.N,), 0, self.cmax + 1).astype(
            jnp.float32
        )
        return Ce, Cc


@dataclasses.dataclass(frozen=True)
class ConstantCarbonSource:
    N: int
    Ce: float = 200.0
    Cc: float = 200.0

    def __post_init__(self):
        # Host-side shape/value validation only (numpy on static
        # metadata, no device syncs -- the analysis lint rules): a
        # mis-shaped Cc would otherwise broadcast or fail slots deep
        # inside a scan.
        if int(self.N) < 1:
            raise ValueError(
                f"ConstantCarbonSource needs N >= 1 clouds, got N={self.N}"
            )
        if np.shape(self.Ce) != ():
            raise ValueError(
                f"Ce must be a scalar intensity, got shape {np.shape(self.Ce)}"
            )
        if np.shape(self.Cc) not in ((), (int(self.N),)):
            raise ValueError(
                f"Cc must be a scalar or [N={self.N}] intensities, got "
                f"shape {np.shape(self.Cc)}"
            )

    def __call__(self, t: Array, key: Array) -> Tuple[Array, Array]:
        del key
        return (
            jnp.asarray(self.Ce, jnp.float32),
            jnp.full((self.N,), self.Cc, jnp.float32),
        )


# 2022-ish UK regional profile parameters: (mean gCO2/kWh, diurnal
# amplitude, wind sensitivity). Region 0 backs the edge server; 1..5 back
# the five clouds (paper uses 6 ESO regions). Tuple-of-tuples so the
# frozen dataclass stays hashable (jit static arg friendly).
_UK_REGIONS = (
    # mean, diurnal_amp, wind_sens
    (180.0, 60.0, 120.0),  # London          (edge)
    (45.0, 20.0, 35.0),    # North Scotland  (hydro/wind heavy)
    (330.0, 80.0, 150.0),  # South Wales     (gas heavy)
    (210.0, 70.0, 130.0),  # Midlands
    (120.0, 50.0, 90.0),   # North West
    (260.0, 75.0, 140.0),  # South East
)

_SLOTS_PER_DAY = 48  # 30-minute slots, as in the ESO dataset


@dataclasses.dataclass(frozen=True)
class UKRegionalTraceSource:
    """Synthetic stand-in for National Grid ESO regional traces (Fig. 3).

    Deterministic in (seed, t): the trace is a pure function, so scan /
    vmap / checkpoint-restart all see the same world.
    """

    N: int = 5
    seed: int = 2022
    regions: tuple = _UK_REGIONS

    def _region_value(self, region: Array, t: Array, key: Array) -> Array:
        params = jnp.asarray(np.asarray(self.regions, np.float32))  # [R,3]
        mean = params[region, 0]
        amp = params[region, 1]
        wind = params[region, 2]
        day_phase = 2.0 * jnp.pi * (t % _SLOTS_PER_DAY) / _SLOTS_PER_DAY
        # Demand peaks around 18:00 -> phase shift; solar dip mid-day.
        diurnal = amp * (
            jnp.sin(day_phase - 2.0 * jnp.pi * 18.0 / 24.0)
            + 0.3 * jnp.sin(2.0 * day_phase)
        )
        # Wind fronts: slow sinusoids with region-coherent + national terms.
        tt = t.astype(jnp.float32)
        national = jnp.sin(2 * jnp.pi * tt / (_SLOTS_PER_DAY * 3.3) + 1.7)
        regional = jnp.sin(
            2 * jnp.pi * tt / (_SLOTS_PER_DAY * 2.1) + region.astype(jnp.float32)
        )
        front = wind * (0.7 * national + 0.3 * regional)
        noise = 25.0 * jax.random.normal(jax.random.fold_in(key, region),
                                         dtype=jnp.float32)
        return jnp.clip(mean + diurnal + front + noise, 5.0, 700.0)

    def __call__(self, t: Array, key: Array) -> Tuple[Array, Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), t)
        regions = jnp.arange(self.N + 1)
        vals = jax.vmap(lambda r: self._region_value(r, t, key))(regions)
        return vals[0], vals[1 : self.N + 1]


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: ndarray field
class TableCarbonSource:
    """Plays back a precomputed table. table: [T, N+1]; column 0 = edge."""

    table: np.ndarray

    def __post_init__(self):
        # Shape-only checks: valid on TRACERS too (simulate_fleet
        # constructs one per vmapped lane with a traced table slab), so
        # no values are read and nothing syncs the device.
        shape = getattr(self.table, "shape", None)
        if shape is None or len(shape) != 2:
            raise ValueError(
                "TableCarbonSource.table must be a [T, N+1] array "
                f"(col 0 = edge), got "
                f"{'no shape' if shape is None else f'shape {tuple(shape)}'}"
            )
        if shape[0] < 1 or shape[1] < 2:
            raise ValueError(
                f"TableCarbonSource.table shape {tuple(shape)} needs at "
                "least 1 row and 2 columns (edge + >=1 cloud); a "
                "mis-sized table would index-garble silently"
            )

    @property
    def N(self) -> int:
        return self.table.shape[1] - 1

    def __call__(self, t: Array, key: Array) -> Tuple[Array, Array]:
        del key
        tab = jnp.asarray(self.table, jnp.float32)
        row = tab[t % tab.shape[0]]
        return row[0], row[1:]


def from_eso_csv(path: str, n_regions: int) -> TableCarbonSource:
    """Loads a National Grid ESO regional forecast CSV export.

    Expected columns: datetime, then one intensity column per region
    (gCO2/kWh). The first region backs the edge, the next `n_regions`
    back the clouds.

    Rows with too few columns or non-numeric intensities are skipped;
    if NO usable row remains (e.g. header-only export, or a file with
    fewer regions than requested) a ValueError spells out what was
    seen instead of failing later in TableCarbonSource.
    """
    rows = []
    skipped = 0
    expected_cols = n_regions + 2  # datetime + edge + n_regions clouds
    with open(path) as f:
        header = f.readline()
        del header
        for line in f:
            if not line.strip():
                continue
            parts = line.strip().split(",")
            if len(parts) < expected_cols:
                skipped += 1
                continue
            try:
                rows.append([float(x) for x in parts[1:expected_cols]])
            except ValueError:
                skipped += 1
    if not rows:
        raise ValueError(
            f"{path}: no usable data rows (expected >= {expected_cols} "
            f"comma-separated columns: datetime, edge, {n_regions} "
            f"cloud regions; skipped {skipped} malformed row(s))"
        )
    table = np.asarray(rows, np.float32)
    return TableCarbonSource(table=table)


# --------------------------------------------------------------------------
# Scenario table generators (fleet sweeps). Each returns a [T, N+1] numpy
# playback table (col 0 = edge region) for TableCarbonSource /
# FleetScenario.carbon. Pure numpy so scenario construction happens once
# on host; the simulator only ever sees the finished table.


def diurnal_table(
    T: int,
    N: int,
    rng: np.random.Generator,
    mean: float = 220.0,
    amp: float = 90.0,
    noise: float = 20.0,
    slots_per_day: int = _SLOTS_PER_DAY,
) -> np.ndarray:
    """Smooth day/night cycle with per-region phase/mean jitter."""
    t = np.arange(T)[:, None]
    phase = rng.uniform(0, 2 * np.pi, (1, N + 1))
    means = mean * rng.uniform(0.6, 1.4, (1, N + 1))
    day = 2 * np.pi * (t % slots_per_day) / slots_per_day
    tab = means + amp * np.sin(day - phase) + noise * rng.normal(
        size=(T, N + 1)
    )
    return np.clip(tab, 5.0, 700.0).astype(np.float32)


def bursty_table(
    T: int,
    N: int,
    rng: np.random.Generator,
    base: float = 120.0,
    spike: float = 450.0,
    p_spike: float = 0.05,
    spike_len: int = 6,
) -> np.ndarray:
    """Low baseline with rare, multi-slot, region-local intensity spikes
    (grid stress events / fossil peaker dispatch)."""
    tab = base * rng.uniform(0.7, 1.3, (T, N + 1))
    starts = rng.random((T, N + 1)) < p_spike
    for dt in range(spike_len):
        rolled = np.roll(starts, dt, axis=0)
        rolled[:dt] = False
        tab = np.where(rolled, tab + spike * (1 - dt / spike_len), tab)
    tab += 15.0 * rng.normal(size=(T, N + 1))
    return np.clip(tab, 5.0, 700.0).astype(np.float32)


def uk_regional_table(
    T: int, N: int, seed: int = 2022, rotate: int = 0
) -> np.ndarray:
    """Materializes UKRegionalTraceSource with the ESO region parameters
    rotated by `rotate` -- a fleet of rotations covers every assignment of
    regions to the edge and clouds (multi-region sweep)."""
    R = len(_UK_REGIONS)
    regions = tuple(
        _UK_REGIONS[(i + rotate) % R] for i in range(N + 1)
    )
    src = UKRegionalTraceSource(N=N, seed=seed, regions=regions)
    return materialize(src, T)


def materialize(source, T: int, key: Array | None = None) -> np.ndarray:
    """Renders any source to a [T, N+1] table (useful for plots/benches)."""
    if key is None:
        key = jax.random.PRNGKey(0)

    def one(t):
        Ce, Cc = source(t, key)
        return jnp.concatenate([Ce[None], Cc])

    return np.asarray(jax.vmap(one)(jnp.arange(T)))
