"""Knapsack oracles for the per-slot surrogate (19).

The paper shows minimizing (19) decouples into one unbounded knapsack per
resource (edge / each cloud), NP-hard in general. For validation we
provide:

  * exact_knapsack_min_py -- exact bounded-knapsack DP in numpy over an
    integral energy grid (weights rounded to a resolution). Ground truth
    for small instances.
  * bounded_knapsack_min  -- the same DP in fixed-shape JAX (scan over
    item types, vectorized over the budget grid), jit-able; used by
    ExactDPPPolicy.

Items: take x_m in {0..cap_m} of type m, cost weight_m * x_m energy,
value score_m * x_m; minimize total value subject to energy <= budget.
Only negative scores can help, so positives are dropped up front.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def exact_knapsack_min_py(
    scores, weights, caps, budget, resolution: int = 2048
):
    """Exact bounded knapsack (minimization) on a discretized energy grid.

    Returns (counts [M], value). Weights are scaled so that `budget`
    maps to `resolution` grid cells; weights round UP (conservative:
    never violates the true budget).
    """
    scores = np.asarray(scores, np.float64)
    weights = np.asarray(weights, np.float64)
    caps = np.asarray(caps, np.float64)
    budget = float(budget)
    M = len(scores)
    if budget <= 0:
        return np.zeros(M), 0.0
    scale = resolution / budget
    iw = np.maximum(np.ceil(weights * scale - 1e-9).astype(int), 1)
    best = np.zeros(resolution + 1)  # best value at each used-energy level
    choice = [dict() for _ in range(resolution + 1)]
    # Bounded knapsack via binary splitting of counts.
    items = []  # (score, weight, type, multiplicity)
    for m in range(M):
        if scores[m] >= 0:
            continue
        cap = int(min(caps[m], budget // weights[m] if weights[m] > 0 else 0))
        k = 1
        while cap > 0:
            take = min(k, cap)
            items.append((scores[m] * take, iw[m] * take, m, take))
            cap -= take
            k *= 2
    for val, w, m, mult in items:
        if w > resolution:
            continue
        for e in range(resolution, w - 1, -1):
            cand = best[e - w] + val
            if cand < best[e] - 1e-12:
                best[e] = cand
                choice[e] = dict(choice[e - w])
                choice[e][m] = choice[e].get(m, 0) + mult
    e_star = int(np.argmin(best))
    counts = np.zeros(M)
    for m, c in choice[e_star].items():
        counts[m] = c
    return counts, float(best[e_star])


def bounded_knapsack_min(
    scores: Array, weights: Array, caps: Array, budget: Array, grid: int = 512
) -> Array:
    """Fixed-shape JAX bounded-knapsack DP (minimization).

    DP over an energy grid of `grid` cells; scan over item types, inner
    scan over that type's binary-split copies. Returns fractional-free
    integer counts [M]. Exact up to the grid discretization (weights
    rounded up), so the result is always feasible w.r.t. the true budget.
    """
    scores = scores.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    caps = caps.astype(jnp.float32)
    budget = jnp.maximum(budget.astype(jnp.float32), 1e-6)
    M = scores.shape[0]
    scale = grid / budget
    iw = jnp.maximum(jnp.ceil(weights * scale - 1e-6), 1.0).astype(jnp.int32)
    cap = jnp.where(
        scores < 0,
        jnp.minimum(caps, jnp.floor(budget / jnp.maximum(weights, 1e-9))),
        0.0,
    ).astype(jnp.int32)

    # Binary splitting: max cap bounded by grid (can't fit more than grid
    # copies of weight>=1 items) -> at most ceil(log2(grid))+1 splits.
    n_splits = int(np.ceil(np.log2(grid))) + 1

    # best[e] = min value using exactly <= e grid-energy; track counts.
    best0 = jnp.zeros((grid + 1,), jnp.float32)
    cnt0 = jnp.zeros((grid + 1, M), jnp.float32)

    def item_body(carry, m):
        best, cnt = carry

        def split_body(carry2, s):
            best, cnt, remaining = carry2
            k = jnp.minimum(2**s, remaining).astype(jnp.float32)
            valid = k > 0
            w = (iw[m].astype(jnp.float32) * k).astype(jnp.int32)
            val = scores[m] * k
            e = jnp.arange(grid + 1)
            src = jnp.clip(e - w, 0, grid)
            cand = jnp.where((e >= w) & valid, best[src] + val, jnp.inf)
            better = cand < best - 1e-9
            new_best = jnp.where(better, cand, best)
            src_cnt = cnt[src] + jnp.zeros((grid + 1, M),
                                           jnp.float32).at[:, m].set(k)
            new_cnt = jnp.where(better[:, None], src_cnt, cnt)
            remaining = remaining - k.astype(jnp.int32)
            return (new_best, new_cnt, remaining), None

        (best, cnt, _), _ = jax.lax.scan(
            split_body, (best, cnt, cap[m]), jnp.arange(n_splits)
        )
        return (best, cnt), None

    (best, cnt), _ = jax.lax.scan(item_body, (best0, cnt0), jnp.arange(M))
    e_star = jnp.argmin(best)
    return cnt[e_star]
