"""Core: the paper's contribution — virtual queueing network + carbon-
intensity based drift-plus-penalty scheduling."""
from repro.core.queueing import (
    Action,
    NetworkSpec,
    NetworkState,
    drift_bound_B,
    emissions,
    init_state,
    is_feasible,
    lyapunov,
    step,
)
from repro.core.policies import (
    CarbonIntensityPolicy,
    ExactDPPPolicy,
    LookaheadDPPPolicy,
    QueueLengthPolicy,
    RandomPolicy,
)
from repro.core.carbon import (
    ConstantCarbonSource,
    RandomCarbonSource,
    TableCarbonSource,
    UKRegionalTraceSource,
    bursty_table,
    diurnal_table,
    uk_regional_table,
)
from repro.core.simulator import (
    FleetScenario,
    FleetSpec,
    PoissonArrivals,
    SimResult,
    UniformArrivals,
    simulate,
    simulate_fleet,
    simulate_vsweep,
    stack_scenarios,
)

__all__ = [
    "Action",
    "NetworkSpec",
    "NetworkState",
    "drift_bound_B",
    "emissions",
    "init_state",
    "is_feasible",
    "lyapunov",
    "step",
    "CarbonIntensityPolicy",
    "ExactDPPPolicy",
    "LookaheadDPPPolicy",
    "QueueLengthPolicy",
    "RandomPolicy",
    "ConstantCarbonSource",
    "RandomCarbonSource",
    "TableCarbonSource",
    "UKRegionalTraceSource",
    "bursty_table",
    "diurnal_table",
    "uk_regional_table",
    "FleetScenario",
    "FleetSpec",
    "PoissonArrivals",
    "SimResult",
    "UniformArrivals",
    "simulate",
    "simulate_fleet",
    "simulate_vsweep",
    "stack_scenarios",
]

from repro.core.extensions import (  # noqa: E402
    AdaptiveVController,
    ThresholdPolicy,
    oracle_emissions_for_work,
    oracle_emissions_horizon,
)

__all__ += [
    "AdaptiveVController",
    "ThresholdPolicy",
    "oracle_emissions_for_work",
    "oracle_emissions_horizon",
]
