"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_0_5b \
      --smoke --steps 50 --seq-len 256 --batch 8 --ckpt-dir /tmp/ckpt

Runs a real training loop on whatever devices exist, with
checkpoint/restart: re-launching with the same --ckpt-dir resumes from
the latest step. On a TPU pod slice the same step function is lowered
with the production-mesh shardings by repro.launch.dryrun's helpers.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import make_batch_fn
from repro.models import build_model
from repro.optim.adamw import AdamW, cosine_schedule, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    train_step = jax.jit(make_train_step(model, opt))
    batch_fn = make_batch_fn(cfg, args.seq_len, args.batch)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    start = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        tree, start, _ = ckpt.restore(
            {"params": params, "opt": opt_state}
        )
        params, opt_state = tree["params"], tree["opt"]
        print(f"resumed from step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = batch_fn(step)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      blocking=False)
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
