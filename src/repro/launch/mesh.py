"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) -- the pod axis
joins data parallelism (hierarchical gradient reduction crosses the
inter-pod links).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
