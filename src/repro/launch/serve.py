"""Serving driver: batched prefill + decode with KV caches, with optional
carbon-aware admission (the paper's policy gating batch execution on live
carbon intensity).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0_5b --smoke \
      --requests 16 --prompt-len 64 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import build_model


def greedy_generate(model, params, prompts, gen_len, cache_len):
    """prompts: [B, S] int32. Returns [B, gen_len] tokens."""
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, {"tokens": t}, cache_len=cache_len)
    )(params, prompts)
    decode = jax.jit(model.decode_step)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(gen_len):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    if cfg.is_encoder_decoder or cfg.family == "vlm":
        raise SystemExit("serve driver targets decoder-only LMs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    n_batches = (args.requests + args.batch - 1) // args.batch
    total_tok = 0
    t0 = time.time()
    for b in range(n_batches):
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size,
                         (args.batch, args.prompt_len)).astype(np.int32)
        )
        toks = greedy_generate(
            model, params, prompts, args.gen_len,
            cache_len=args.prompt_len + args.gen_len + 1,
        )
        total_tok += toks.size
        print(f"batch {b}: generated {toks.shape} "
              f"first tokens {np.asarray(toks[0,:8])}")
    dt = time.time() - t0
    print(f"served {args.requests} reqs, {total_tok} tokens "
          f"in {dt:.1f}s ({total_tok/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
