import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation), record
memory_analysis / cost_analysis / per-collective byte counts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4_9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 2x16x16 only

Artifacts: one JSON per cell under artifacts/dryrun/ (consumed by
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run / §Roofline).
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.distributed import api as dist_api
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim.adamw import AdamW, make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax, a list of
    per-computation dicts on jax<=0.4.x -- normalize to one dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def parse_collective_bytes(hlo_text: str):
    """Sums result-shape bytes of every collective op in post-SPMD HLO.

    Accounting (per-device traffic estimate, ring algorithms):
      all-reduce       2x result bytes
      all-gather       1x result bytes
      reduce-scatter   1x operand bytes (~= result x group)
      all-to-all       1x result bytes
      collective-permute 1x result bytes
    """
    totals = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    # e.g.:  %all-gather.3 = bf16[4,1024,512]{...} all-gather(...)
    pat = re.compile(
        r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^ ]*\s*,?\s*)+)\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(",
    )
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

    for m in pat.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        nbytes = 0.0
        for sm in shape_pat.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        w = 2.0 if op == "all-reduce" else 1.0
        totals[op] += w * nbytes
        counts[op] += 1
    return totals, counts


def _spec_tree_to_json(tree):
    return jax.tree.map(
        lambda s: str(getattr(s, "spec", s)), tree,
        is_leaf=lambda x: hasattr(x, "spec"),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               seq_parallel: bool = False):
    """Lower + compile one cell; returns the result record dict."""
    cfg = registry.get_config(arch)
    ok, why = cfg.supports_shape(shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    kind = registry.SHAPES[shape_name]["kind"]
    t0 = time.time()

    param_specs = model.param_specs()
    p_shard, fallbacks = sh.param_shardings(mesh, param_specs, cfg)

    if kind == "train":
        opt = AdamW(lr=1e-4)
        opt_state_specs = jax.eval_shape(opt.init, param_specs)
        o_shard, _ = sh.param_shardings(mesh, opt_state_specs.m, cfg)
        opt_shard = type(opt_state_specs)(
            m=o_shard,
            v=jax.tree.map(lambda s: s, o_shard),
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        )
        batch_specs = model.input_specs(shape_name)
        b_shard = sh.batch_shardings(mesh, batch_specs)
        fn = make_train_step(model, opt)
        args = (param_specs, opt_state_specs, batch_specs)
        in_shard = (p_shard, opt_shard, b_shard)
        out_shard = (p_shard, opt_shard, None)
    elif kind == "prefill":
        batch_specs = model.input_specs(shape_name)
        b_shard = sh.batch_shardings(mesh, batch_specs)
        s = registry.SHAPES[shape_name]
        cache_spec = model.cache_specs(s["seq_len"], s["global_batch"])
        c_shard = sh.cache_shardings(mesh, cache_spec, cfg)

        def fn(params, batch):
            return model.prefill(params, batch)

        args = (param_specs, batch_specs)
        in_shard = (p_shard, b_shard)
        out_shard = (None, c_shard)
    else:  # decode
        s = registry.SHAPES[shape_name]
        dspec = model.input_specs(shape_name)
        tok_shard = sh.batch_shardings(mesh, dspec["token"])
        c_shard = sh.cache_shardings(mesh, dspec["cache"], cfg)

        def fn(params, token, cache):
            return model.decode_step(params, token, cache)

        args = (param_specs, dspec["token"], dspec["cache"])
        in_shard = (p_shard, tok_shard, c_shard)
        out_shard = (None, c_shard)

    rules = sh.activation_rule_table(mesh, cfg, seq_parallel=seq_parallel)
    with mesh, dist_api.activation_rules(
        rules, mesh=mesh, dp_axes=sh.dp_axes(mesh), ep_axis="model"
    ):
        jfn = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll_bytes, coll_counts = parse_collective_bytes(hlo)
    t1 = time.time()

    n_dev = mesh.size
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "seq_parallel": seq_parallel,
        "status": "ok",
        "compile_seconds": round(t1 - t0, 1),
        "memory": {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            ) if hasattr(mem, k)
        },
        "cost": {
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_accessed_per_device": float(
                cost.get("bytes accessed", 0.0)
            ),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        },
        "collectives": {
            "bytes": coll_bytes,
            "counts": coll_counts,
            "total_bytes": sum(coll_bytes.values()),
        },
        "sharding_fallbacks": [
            {"path": p, "dim": d, "axis": str(a)} for p, d, a in fallbacks
        ][:40],
        "model": {
            "total_params": cfg.total_params(),
            "active_params": cfg.active_params(),
        },
    }
    return record


def cell_path(arch, shape_name, mesh_tag, seq_parallel=False):
    sp = "__sp" if seq_parallel else ""
    return ARTIFACTS / f"{arch}__{shape_name}__{mesh_tag}{sp}.json"


# ---------------------------------------------------------------------------
# Cost calibration: XLA's HloCostAnalysis counts a while-loop body ONCE, so
# scanned layer stacks under-report flops/bytes/collective-bytes by the trip
# count. We compile fully-unrolled 1-unit and 2-unit variants (unit = layer,
# hybrid super-block, or enc+dec layer pair) and extrapolate affinely:
#     cost(L) = cost(1) + (cost(2) - cost(1)) * (L - 1)
# which is exact for homogeneous stacks (embeddings/CE live in the
# intercept). Verified against the calibration identity in tests.
# ---------------------------------------------------------------------------

def _reduced_cfg(cfg, units: int):
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, n_layers=units * cfg.attn_every, unroll_scans=True
        )
    if cfg.is_encoder_decoder:
        return dataclasses.replace(
            cfg, n_layers=units, n_encoder_layers=units, unroll_scans=True
        )
    return dataclasses.replace(cfg, n_layers=units, unroll_scans=True)


def _full_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _cell_costs(cfg, shape_name: str, multi_pod: bool,
                seq_parallel: bool = False):
    """Compile one (possibly reduced) config variant; return raw costs."""
    from repro.models.model import Model

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    kind = registry.SHAPES[shape_name]["kind"]
    param_specs = model.param_specs()
    p_shard, _ = sh.param_shardings(mesh, param_specs, cfg)

    if kind == "train":
        opt = AdamW(lr=1e-4)
        opt_state_specs = jax.eval_shape(opt.init, param_specs)
        o_shard, _ = sh.param_shardings(mesh, opt_state_specs.m, cfg)
        opt_shard = type(opt_state_specs)(
            m=o_shard, v=o_shard,
            step=jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec()),
        )
        batch_specs = model.input_specs(shape_name)
        b_shard = sh.batch_shardings(mesh, batch_specs)
        fn = make_train_step(model, opt)
        args = (param_specs, opt_state_specs, batch_specs)
        in_shard = (p_shard, opt_shard, b_shard)
        out_shard = (p_shard, opt_shard, None)
    elif kind == "prefill":
        batch_specs = model.input_specs(shape_name)
        b_shard = sh.batch_shardings(mesh, batch_specs)
        s = registry.SHAPES[shape_name]
        cache_spec = model.cache_specs(s["seq_len"], s["global_batch"])
        c_shard = sh.cache_shardings(mesh, cache_spec, cfg)

        def fn(params, batch):
            return model.prefill(params, batch)

        args = (param_specs, batch_specs)
        in_shard = (p_shard, b_shard)
        out_shard = (None, c_shard)
    else:
        dspec = model.input_specs(shape_name)
        tok_shard = sh.batch_shardings(mesh, dspec["token"])
        c_shard = sh.cache_shardings(mesh, dspec["cache"], cfg)

        def fn(params, token, cache):
            return model.decode_step(params, token, cache)

        args = (param_specs, dspec["token"], dspec["cache"])
        in_shard = (p_shard, tok_shard, c_shard)
        out_shard = (None, c_shard)

    rules = sh.activation_rule_table(mesh, cfg, seq_parallel=seq_parallel)
    with mesh, dist_api.activation_rules(
        rules, mesh=mesh, dp_axes=sh.dp_axes(mesh), ep_axis="model"
    ):
        compiled = jax.jit(
            fn, in_shardings=in_shard, out_shardings=out_shard
        ).lower(*args).compile()
    cost = _cost_dict(compiled)
    coll_bytes, _ = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": sum(coll_bytes.values()),
        "collective_by_op": coll_bytes,
    }


def calibrate_cell(arch: str, shape_name: str, multi_pod: bool,
                   seq_parallel: bool = False):
    cfg = registry.get_config(arch)
    ok, _ = cfg.supports_shape(shape_name)
    if not ok:
        return None
    L = _full_units(cfg)
    c1 = _cell_costs(_reduced_cfg(cfg, 1), shape_name, multi_pod,
                     seq_parallel)
    c2 = _cell_costs(_reduced_cfg(cfg, 2), shape_name, multi_pod,
                     seq_parallel)
    # per-unit deltas clamped at 0: XLA occasionally optimizes the 2-unit
    # module harder than the 1-unit one (CSE across layers), which would
    # extrapolate negative -- physically impossible.
    corrected = {
        k: c1[k] + max(c2[k] - c1[k], 0.0) * (L - 1)
        for k in ("flops", "bytes", "collective_bytes")
    }
    corrected["collective_by_op"] = {
        op: c1["collective_by_op"][op]
        + max(c2["collective_by_op"][op] - c1["collective_by_op"][op], 0.0)
        * (L - 1)
        for op in c1["collective_by_op"]
    }
    corrected["units_full"] = L
    corrected["nonmonotone"] = bool(
        any(c2[k] < 0.98 * c1[k] for k in ("flops", "bytes"))
    )
    corrected["per_unit"] = {
        k: max(c2[k] - c1[k], 0.0)
        for k in ("flops", "bytes", "collective_bytes")
    }
    return corrected


def run_calibration(archs, shapes, meshes, force=False,
                    seq_parallel=False):
    n = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = "multi" if multi else "single"
                out = cell_path(arch, shape_name, tag, seq_parallel)
                if not out.exists():
                    continue
                rec = json.loads(out.read_text())
                if rec.get("status") != "ok":
                    continue
                if "cost_corrected" in rec and not force:
                    continue
                try:
                    corrected = calibrate_cell(arch, shape_name, multi,
                                               seq_parallel)
                except Exception as e:
                    print(f"[cal-FAIL] {arch} x {shape_name} x {tag}: "
                          f"{str(e)[:200]}")
                    continue
                if corrected is None:
                    continue
                rec["cost_corrected"] = corrected
                out.write_text(json.dumps(rec, indent=2))
                n += 1
                print(f"[cal] {arch} x {shape_name} x {tag}: "
                      f"{corrected['flops']/1e12:.2f} TF/dev, "
                      f"{corrected['bytes']/1e9:.1f} GB/dev, "
                      f"coll {corrected['collective_bytes']/1e9:.2f} GB")
    print(f"calibrated {n} cells")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="add loop-corrected cost numbers to existing cells")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel activation sharding (artifacts "
                         "suffixed __sp)")
    ap.add_argument("--print-hlo-collectives", action="store_true")
    args = ap.parse_args()

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(registry.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.calibrate:
        run_calibration(archs, shapes, meshes, force=args.force,
                        seq_parallel=args.seq_parallel)
        return 0

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = "multi" if multi else "single"
                out = cell_path(arch, shape_name, tag, args.seq_parallel)
                if out.exists() and not args.force:
                    rec = json.loads(out.read_text())
                    print(f"[cached] {arch} x {shape_name} x {tag}: "
                          f"{rec['status']}")
                    n_ok += rec["status"] == "ok"
                    n_skip += rec["status"] == "skipped"
                    n_fail += rec["status"] == "failed"
                    continue
                try:
                    rec = lower_cell(arch, shape_name, multi,
                                     seq_parallel=args.seq_parallel)
                except Exception as e:  # a failure here is a sharding bug
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": tag,
                        "status": "failed", "error": str(e)[:2000],
                        "traceback": traceback.format_exc()[-4000:],
                    }
                out.write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_fail += status == "failed"
                if status == "ok":
                    mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
                    print(
                        f"[ok] {arch} x {shape_name} x {tag}: "
                        f"compile {rec['compile_seconds']}s, "
                        f"temp {mem_gb:.2f} GB/dev, "
                        f"coll {rec['collectives']['total_bytes']/1e9:.2f} GB"
                    )
                elif status == "skipped":
                    print(f"[skip] {arch} x {shape_name} x {tag}: "
                          f"{rec['reason']}")
                else:
                    print(f"[FAIL] {arch} x {shape_name} x {tag}: "
                          f"{rec['error'][:200]}")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
