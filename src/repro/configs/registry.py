"""Architecture registry: unified ModelConfig + the 10 assigned archs.

Every assigned architecture gets a module `src/repro/configs/<id>.py`
exporting `CONFIG` (full size, dry-run only) and `SMOKE` (reduced config
of the same family, used by CPU smoke tests). Select with
``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

# Input-shape cells assigned to the LM family (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

ARCH_IDS = (
    "starcoder2_15b",
    "internlm2_20b",
    "glm4_9b",
    "qwen1_5_0_5b",
    "arctic_480b",
    "qwen2_moe_a2_7b",
    "paligemma_3b",
    "seamless_m4t_medium",
    "mamba2_1_3b",
    "jamba_1_5_large_398b",
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int  # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu | relu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # glm4 uses partial rotary
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0  # routed top-k
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # expert hidden dim (0 => d_ff)
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel
    moe_every: int = 1  # apply MoE each `moe_every` layers (jamba: 2)
    moe_path: str = "capacity"  # capacity (production) | dense (exact oracle)
    moe_capacity_factor: float = 1.25
    ep_axis: int = 16  # experts padded to a multiple of this (EP mesh axis)
    # --- SSM (mamba2 / jamba) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid ---
    attn_every: int = 0  # jamba: one attention layer per 8 layers
    # --- enc-dec / frontends ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    source_len: int = 4096  # encoder input length for enc-dec dry-run cells
    prefix_len: int = 0  # vlm: image-patch prefix (prefix-LM masking)
    frontend_stub: str = ""  # "patch" | "frames" | ""
    # --- numerics / execution ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "block"  # none | block
    attn_chunk: int = 1024  # query-chunked (flash-style) attention block
    logit_chunk: int = 2048  # chunked unembed+CE
    use_pallas: bool = False  # Pallas kernels on TPU; jnp reference elsewhere
    # Roofline calibration: XLA's HloCostAnalysis counts a while-loop body
    # ONCE, so scanned stacks under-report flops/bytes by the trip count.
    # unroll_scans=True lowers every scan fully unrolled; the dry-run's
    # --calibrate pass compiles L=1/L=2 unrolled variants and extrapolates.
    unroll_scans: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ---- parameter counting (roofline MODEL_FLOPS uses these) ----
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        qkv = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * hd
        out = self.n_heads * hd * self.d_model
        return qkv + out

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * self.d_model * d_ff

    def _ssm_params(self) -> int:
        di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
        in_proj = self.d_model * (2 * di + 2 * ds + nh)  # x,z,B,C,dt
        out_proj = di * self.d_model
        conv = self.ssm_conv * (di + 2 * ds)
        return in_proj + out_proj + conv + 2 * nh  # + A_log, D

    def _layer_counts(self) -> Tuple[int, int]:
        """(n_attention_layers, n_ssm_layers) over the decoder stack."""
        if self.family == "ssm":
            return 0, self.n_layers
        if self.family == "hybrid":
            n_attn = self.n_layers // self.attn_every
            return n_attn, self.n_layers - n_attn
        return self.n_layers, 0

    def total_params(self) -> int:
        n_attn, n_ssm = self._layer_counts()
        p = n_attn * self._attn_params() + n_ssm * self._ssm_params()
        moe_ff = self.moe_d_ff or self.d_ff
        if self.n_experts:
            n_moe_layers = self.n_layers // self.moe_every
            n_dense_layers = self.n_layers - n_moe_layers
            p += n_moe_layers * (
                self.n_experts * self._mlp_params(moe_ff)
                + self.n_shared_experts * self._mlp_params(moe_ff)
                + self.d_model * self.n_experts  # router
                + (self._mlp_params(self.d_ff) if self.moe_dense_residual else 0)
            )
            p += n_dense_layers * self._mlp_params(self.d_ff)
        elif self.d_ff:
            p += self.n_layers * self._mlp_params(self.d_ff)
        if self.is_encoder_decoder:
            # encoder stack + cross-attention in decoder
            p += self.n_encoder_layers * (
                self._attn_params() + self._mlp_params(self.d_ff)
            )
            p += self.n_layers * self._attn_params()  # cross-attn
        p += self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return p

    def active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.total_params()
        n_attn, n_ssm = self._layer_counts()
        p = n_attn * self._attn_params() + n_ssm * self._ssm_params()
        moe_ff = self.moe_d_ff or self.d_ff
        n_moe_layers = self.n_layers // self.moe_every
        n_dense_layers = self.n_layers - n_moe_layers
        p += n_moe_layers * (
            self.n_experts_active * self._mlp_params(moe_ff)
            + self.n_shared_experts * self._mlp_params(moe_ff)
            + self.d_model * self.n_experts
            + (self._mlp_params(self.d_ff) if self.moe_dense_residual else 0)
        )
        p += n_dense_layers * self._mlp_params(self.d_ff)
        p += self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return p

    def supports_shape(self, shape: str) -> Tuple[bool, str]:
        """Whether a dry-run cell applies (see DESIGN.md §Arch-applicability)."""
        if shape == "long_500k" and self.family not in ("ssm", "hybrid"):
            return False, "long_500k needs sub-quadratic attention; " \
                "this arch is pure full-attention (skip per brief)"
        return True, ""


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE
