"""SeamlessM4T-medium [arXiv:2308.11596; hf] — encoder-decoder multimodal
backbone. The speech frontend is a STUB (input_specs provides precomputed
frame embeddings [B, source_len, d_model]). 12L encoder + 12L decoder with
cross-attention, GELU, sinusoidal positions on the encoder, RoPE-free
decoder (learned-free; absolute sinusoidal). Vocab padded 256206 -> 256256
for TP divisibility (see distributed/sharding.py)."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    activation="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    n_encoder_layers=12,
    source_len=4096,
    frontend_stub="frames",
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    activation="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    n_encoder_layers=2,
    source_len=32,
    frontend_stub="frames",
    param_dtype="float32",
    compute_dtype="float32",
)
