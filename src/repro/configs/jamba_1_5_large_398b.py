"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] — hybrid Mamba+attention
with 1:7 interleave (one GQA attention layer per 8), MoE 16 experts top-2
on every other layer. 72 layers, d_model 8192."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    activation="swiglu",
    n_experts=16,
    n_experts_active=2,
    moe_d_ff=24576,
    moe_every=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=8,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=4,  # one super-block of attn_every=4 -> 1 attn + 3 mamba
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    activation="swiglu",
    n_experts=4,
    n_experts_active=2,
    moe_path="dense",
    ep_axis=2,
    moe_d_ff=192,
    moe_every=2,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=8,
    attn_every=4,
    param_dtype="float32",
    compute_dtype="float32",
)
