"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSM with the SSD
(state-space duality) chunked algorithm. 48 layers, d_model 2048,
d_inner = 2*d_model, head_dim 64, d_state 128."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=8,
    param_dtype="float32",
    compute_dtype="float32",
)
