"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts
top-4 + 4 shared experts, MHA kv=16. Expert count padded 60 -> 64 on the
EP mesh axis (pads masked out of routing; see distributed/sharding.py)."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,  # shared-path FFN capacity
    vocab_size=151936,
    activation="swiglu",
    n_experts=60,
    n_experts_active=4,
    n_shared_experts=4,
    moe_d_ff=1408,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    activation="swiglu",
    n_experts=6,
    n_experts_active=2,
    n_shared_experts=2,
    moe_path="dense",
    ep_axis=2,
    moe_d_ff=96,
    param_dtype="float32",
    compute_dtype="float32",
)
