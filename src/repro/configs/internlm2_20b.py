"""InternLM2-20B [arXiv:2403.17297; hf] — dense, GQA kv=8, RoPE, SwiGLU."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    activation="swiglu",
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="internlm2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    activation="swiglu",
    param_dtype="float32",
    compute_dtype="float32",
)
