"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: every layer has a dense residual FFN *in parallel* with a
128-expert top-2 MoE. GQA kv=8."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    activation="swiglu",
    n_experts=128,
    n_experts_active=2,
    moe_d_ff=4864,
    moe_dense_residual=True,
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    n_experts=8,
    n_experts_active=2,
    moe_path="dense",
    ep_axis=2,
    moe_d_ff=128,
    moe_dense_residual=True,
    param_dtype="float32",
    compute_dtype="float32",
)
