"""GLM4-9B [hf:THUDM/glm-4-9b] — dense, GQA kv=2, partial RoPE (half the
head dim rotates), SwiGLU."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    activation="swiglu",
    rope_fraction=0.5,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="glm4-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=224,
    vocab_size=512,
    activation="swiglu",
    rope_fraction=0.5,
    param_dtype="float32",
    compute_dtype="float32",
)
