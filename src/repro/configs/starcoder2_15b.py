"""StarCoder2-15B [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE,
LayerNorm + GELU MLP (non-gated), learned biasless embeddings."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    norm="layernorm",
    rope_theta=100000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    activation="gelu",
    norm="layernorm",
    param_dtype="float32",
    compute_dtype="float32",
)
