"""PaliGemma-3B [arXiv:2407.07726; hf] — SigLIP vision frontend (STUB:
input_specs provides precomputed patch embeddings) + Gemma-2B decoder:
MQA (kv=1), head_dim 256, GeGLU, prefix-LM attention over the image
prefix, tied embeddings."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    activation="geglu",
    tie_embeddings=True,
    prefix_len=256,
    frontend_stub="patch",
)

SMOKE = ModelConfig(
    name="paligemma-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
    activation="geglu",
    tie_embeddings=True,
    prefix_len=8,
    frontend_stub="patch",
    param_dtype="float32",
    compute_dtype="float32",
)
