"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, MHA (kv=16), QKV bias,
SwiGLU, tied embeddings."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=176,
    vocab_size=512,
    activation="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
