"""Paper §V experimental setup (Table I + network constants).

M=5 AI-training task types on ImageNet; N=5 homogeneous clouds.
Energy in kWh. Also exposes `lm_workloads()` which extends the task-type
set with the assigned LM architectures, costed from their per-step FLOPs
(6*N_active*D) at a TPU-v5e J/FLOP — the bridge between the paper's
scheduler and this repo's training data plane.
"""
from __future__ import annotations

import numpy as np

from repro.core.queueing import NetworkSpec

# Table I: (model, pc kWh (all clouds), pe kWh)
TABLE_I = (
    ("ResNet50", 74.0, 3.45),
    ("InceptionV3", 97.0, 3.45),
    ("DenseNet121", 54.0, 3.45),
    ("SqueezeNet", 16.0, 3.45),
    ("MobileNetV2", 5.8, 3.45),
)

P_EDGE = 4000.0          # kWh per slot
P_CLOUD = 30000.0        # kWh per slot, each of N=5 clouds
N_CLOUDS = 5
A_MAX = 400              # a_m(t) ~ U{0..400}
V_PAPER = 0.05
C_MAX_RANDOM = 700       # random carbon intensity ~ U{0..700}


def paper_spec() -> NetworkSpec:
    pe = np.array([row[2] for row in TABLE_I], np.float32)
    pc = np.tile(
        np.array([row[1] for row in TABLE_I], np.float32)[:, None],
        (1, N_CLOUDS),
    )
    return NetworkSpec(
        pe=pe, pc=pc, Pe=P_EDGE, Pc=np.full((N_CLOUDS,), P_CLOUD, np.float32)
    )


# ---------------------------------------------------------------------------
# Bridge: LM architectures as task types.
# Energy per "task" = training-step bundle of `steps_per_task` steps:
#   FLOPs = 6 * N_active_params * tokens_per_step * steps_per_task
#   energy_kWh = FLOPs / (MFU * peak_flops) * chip_power_kW / 3600 * chips
# We fold chips out by using per-chip seconds * kW; what matters to the
# scheduler is only the *relative* pc and the budget scale.
_V5E_PEAK = 197e12      # bf16 FLOP/s
_V5E_KW = 0.25          # ~chip power (kW) under load, incl. amortized host
_MFU = 0.4


def lm_task_energy_kwh(
    n_active_params: float, tokens_per_step: float, steps_per_task: int = 100
) -> float:
    flops = 6.0 * n_active_params * tokens_per_step * steps_per_task
    seconds = flops / (_MFU * _V5E_PEAK)
    return seconds / 3600.0 * _V5E_KW


def lm_workloads(arch_ids=None, n_clouds: int = N_CLOUDS) -> NetworkSpec:
    """NetworkSpec whose task types are the assigned LM architectures."""
    from repro.configs import registry

    arch_ids = arch_ids or registry.ARCH_IDS
    pcs, pes = [], []
    for aid in arch_ids:
        cfg = registry.get_config(aid)
        tokens = 4096 * 8  # one micro-bundle of train_4k tokens
        pc = lm_task_energy_kwh(cfg.active_params(), tokens)
        # edge send cost ~ checkpoint-shard + data shard transfer at
        # 0.023 kWh/GB (paper's Malmodin-Lunden figure).
        gb = cfg.active_params() * 2 / 1e9 * 0.05  # 5% of weights per task
        pes.append(max(gb * 0.023, 1e-3))
        pcs.append(pc)
    pe = np.asarray(pes, np.float32)
    pc = np.tile(np.asarray(pcs, np.float32)[:, None], (1, n_clouds))
    # Budgets scaled so the mean load is ~0.35 like the paper's setup.
    mean_demand = float(np.mean(pc)) * (A_MAX / 2) * len(arch_ids)
    return NetworkSpec(
        pe=pe,
        pc=pc,
        Pe=float(np.mean(pe) * (A_MAX / 2) * len(arch_ids) / 0.85),
        Pc=np.full((n_clouds,), mean_demand / n_clouds / 0.35, np.float32),
    )
