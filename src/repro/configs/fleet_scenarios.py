"""Fleet scenario registry: named region x workload-mix generators that
stack into one `FleetScenario` for `simulate_fleet`.

Each generator produces ONE simulation instance
(NetworkSpec, carbon_table [Tc, N+1], arrival_amax [M]) from an
instance-local RNG; `build_fleet` fans a list of scenario names out to
`per_kind` instances each and stacks them, so

    fleet = build_fleet(["diurnal", "bursty"], per_kind=32)
    res = jax.jit(lambda k: simulate_fleet(policy, fleet, T, k))(key)

sweeps 64 scenarios in one compiled call. Scenarios:

  * diurnal             -- paper workload mix under smooth day/night
                           carbon cycles with per-region phase jitter.
  * diurnal-slack       -- diurnal carbon at ~60% load: the capacity
                           headroom a forecast-driven lookahead policy
                           needs to shift work into intensity troughs.
  * bursty              -- rare multi-slot carbon spikes + heavy-tailed
                           per-type arrival caps (flash crowds).
  * heterogeneous-fleet -- per-instance scaling of task energies and
                           cloud budgets (mixed hardware generations).
  * multi-region-uk     -- National-Grid-ESO-style UK regional traces
                           with the region->site assignment rotated per
                           instance.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.paper_workloads import A_MAX, paper_spec
from repro.core.carbon import (
    _UK_REGIONS,
    bursty_table,
    diurnal_table,
    uk_regional_table,
)
from repro.core.queueing import NetworkSpec
from repro.core.simulator import FleetScenario, stack_scenarios

Instance = Tuple[NetworkSpec, np.ndarray, np.ndarray]


def _base(M: int, N: int) -> NetworkSpec:
    """Paper Table-I spec tiled/truncated to (M, N)."""
    base = paper_spec()
    pe = np.resize(np.asarray(base.pe, np.float32), M)
    pc_col = np.resize(np.asarray(base.pc, np.float32)[:, 0], M)
    pc = np.tile(pc_col[:, None], (1, N))
    scale = (M / base.M) * (N / base.N)
    return NetworkSpec(
        pe=pe,
        pc=pc,
        Pe=float(base.Pe) * (M / base.M),
        Pc=np.full((N,), float(np.asarray(base.Pc)[0]) * scale / N,
                   np.float32),
    )


def diurnal(M: int, N: int, Tc: int, rng: np.random.Generator) -> Instance:
    spec = _base(M, N)
    amax = np.full((M,), float(A_MAX), np.float32)
    return spec, diurnal_table(Tc, N, rng), amax


def bursty(M: int, N: int, Tc: int, rng: np.random.Generator) -> Instance:
    spec = _base(M, N)
    # Heavy-tailed workload mix: a few hot types, many cold ones.
    amax = np.round(
        A_MAX * rng.pareto(1.5, M).clip(0.05, 4.0)
    ).astype(np.float32)
    return spec, bursty_table(Tc, N, rng), amax


def heterogeneous_fleet(
    M: int, N: int, Tc: int, rng: np.random.Generator
) -> Instance:
    base = _base(M, N)
    # Mixed hardware generations: per-cloud energy efficiency and budget
    # spread, per-type edge-link cost spread.
    eff = rng.uniform(0.5, 2.0, (1, N)).astype(np.float32)
    spec = dataclasses.replace(
        base,
        pe=np.asarray(base.pe) * rng.uniform(0.5, 2.0, M).astype(np.float32),
        pc=np.asarray(base.pc) * eff,
        Pc=np.asarray(base.Pc) * rng.uniform(0.4, 1.6, N).astype(np.float32),
    )
    amax = np.round(A_MAX * rng.uniform(0.3, 1.5, M)).astype(np.float32)
    return spec, diurnal_table(Tc, N, rng), amax


def diurnal_slack(
    M: int, N: int, Tc: int, rng: np.random.Generator
) -> Instance:
    """Diurnal carbon with ~40% capacity headroom: arrivals scaled down
    so deferring work out of intensity peaks is actually feasible. This
    is the regime where forecast-driven lookahead pays off (the plain
    `diurnal` scenario runs near saturation, which caps how much work
    any planner can shift into the troughs)."""
    spec = _base(M, N)
    amax = np.full((M,), round(0.6 * A_MAX), np.float32)
    return spec, diurnal_table(Tc, N, rng, amp=110.0, noise=15.0), amax


def overload(M: int, N: int, Tc: int, rng: np.random.Generator) -> Instance:
    """Offered load ~1.8x the plain diurnal scenario (which already runs
    near saturation): no policy can clear these queues, so backlog grows
    without bound unless the deadline layer's admission control sheds.
    The graceful-overload scenario for `with_deadlines` + `shed_on`."""
    spec = _base(M, N)
    amax = np.round(
        1.8 * A_MAX * rng.uniform(0.9, 1.1, M)
    ).astype(np.float32)
    return spec, diurnal_table(Tc, N, rng), amax


def multi_region_uk(
    M: int, N: int, Tc: int, rng: np.random.Generator
) -> Instance:
    spec = _base(M, N)
    amax = np.full((M,), float(A_MAX), np.float32)
    table = uk_regional_table(
        Tc, N, seed=int(rng.integers(1 << 30)),
        rotate=int(rng.integers(len(_UK_REGIONS))),
    )
    return spec, table, amax


SCENARIOS: Dict[str, Callable[..., Instance]] = {
    "diurnal": diurnal,
    "diurnal-slack": diurnal_slack,
    "bursty": bursty,
    "heterogeneous-fleet": heterogeneous_fleet,
    "multi-region-uk": multi_region_uk,
    "overload": overload,
}


# ---------------------------------------------------------------------------
# WAN topology scenarios (network subsystem). Each generator returns
# (NetworkSpec, carbon_table, amax, LinkGraph); `build_network_fleet`
# stacks them into a FleetScenario whose `graph` axis routes every lane
# through the transfer layer. Task data volumes scale with compute cost
# (bigger models move bigger artifacts): size[m] = pc[m, 0] / 20.


def _task_sizes(spec: NetworkSpec) -> np.ndarray:
    return (np.asarray(spec.pc, np.float32)[:, 0] / 20.0).astype(
        np.float32
    )


def star(M: int, N: int, Tc: int, rng: np.random.Generator):
    """Hub-and-spoke: one finite direct link per cloud. The mildest
    topology -- bandwidth caps bite only under bursts."""
    from repro.network.graph import star_graph

    spec = _base(M, N)
    size = _task_sizes(spec)
    load = float(0.5 * A_MAX * size.sum())  # mean size-units/slot offered
    graph = star_graph(
        M, N, rng, size=size,
        bw_range=(0.25 * load, 0.7 * load),
    )
    amax = np.full((M,), float(A_MAX), np.float32)
    return spec, diurnal_table(Tc, N, rng), amax, graph


def congested_uplink(M: int, N: int, Tc: int, rng: np.random.Generator):
    """Per cloud: a wide but dirty default uplink and a clean, cheap
    alternate riding a green backbone whose total bandwidth sits just
    at the offered load -- the alternates saturate, so a route-aware
    policy must trade clean-but-queued against dirty-but-instant while
    a transfer-blind one burns the dirty primaries throughout. The
    green backbone is priced in the LAST cloud's region (row index N),
    whose intensity column is scaled down to backbone levels."""
    from repro.network.graph import congested_uplink_graph

    spec = _base(M, N)
    size = _task_sizes(spec)
    amax = np.full((M,), round(0.6 * A_MAX), np.float32)
    load = float(0.5 * 0.6 * A_MAX * size.sum())  # size-units/slot
    graph = congested_uplink_graph(
        M, N, rng, size=size,
        clean_bw=1.0 * load / N, dirty_bw=10.0 * load / N,
    )
    table = diurnal_table(Tc, N, rng)
    table[:, N] = np.clip(0.25 * table[:, N], 5.0, 120.0)
    return spec, table, amax, graph


def multi_region_uk_wan(
    M: int, N: int, Tc: int, rng: np.random.Generator
):
    """ESO-style regional traces with direct and relayed routes: relays
    cost ~1.8x the transfer energy but can ride a decorrelated
    wind-front trough in another region."""
    from repro.network.graph import multi_region_wan_graph

    spec = _base(M, N)
    size = _task_sizes(spec)
    amax = np.full((M,), float(A_MAX), np.float32)
    load = float(0.5 * A_MAX * size.sum())
    graph = multi_region_wan_graph(M, N, rng, size=size)
    # Direct links are provisioned for the full offered load (a
    # transfer-blind baseline must not be throughput-starved -- the
    # comparison is about carbon, not capacity); relays add green
    # arbitrage with less headroom.
    L = graph.bw.shape[0]
    direct = np.arange(L) % 2 == 0
    bw = np.where(direct, load, 0.35 * load).astype(np.float32)
    graph = graph._replace(
        bw=jnp.asarray(bw * rng.uniform(0.9, 1.1, L).astype(np.float32))
    )
    table = uk_regional_table(
        Tc, N, seed=int(rng.integers(1 << 30)),
        rotate=int(rng.integers(len(_UK_REGIONS))),
    )
    return spec, table, amax, graph


NETWORK_SCENARIOS: Dict[str, Callable] = {
    "star": star,
    "congested-uplink": congested_uplink,
    "multi-region-uk-wan": multi_region_uk_wan,
}


def build_network_fleet(
    kinds: Sequence[str] = ("congested-uplink", "multi-region-uk-wan"),
    per_kind: int = 16,
    M: int = 5,
    N: int = 5,
    Tc: int = 96,
    seed: int = 0,
) -> FleetScenario:
    """WAN twin of `build_fleet`: stacks `per_kind` instances of every
    named topology scenario into one FleetScenario whose stacked
    LinkGraph routes all lanes through the transfer layer. Graphs must
    share (M, N, L), so only same-route-count kinds can mix: the
    default stacks the two 2N-route topologies; "star" (N routes)
    must be built on its own."""
    instances, graphs = [], []
    for i, kind in enumerate(kinds):
        try:
            gen = NETWORK_SCENARIOS[kind]
        except KeyError:
            raise KeyError(
                f"unknown network scenario {kind!r}; registered: "
                f"{sorted(NETWORK_SCENARIOS)}"
            ) from None
        for j in range(per_kind):
            rng = np.random.default_rng((seed, 1 + i, j))
            spec, table, amax, graph = gen(M, N, Tc, rng)
            instances.append((spec, table, amax))
            graphs.append(graph)
    return stack_scenarios(instances, graphs=graphs)


# ---------------------------------------------------------------------------
# Fault scenario registry (repro.faults). Each generator returns one
# lane's FaultParams from an instance-local RNG; `with_faults` stacks
# per-lane draws onto a fleet's `faults` axis so one compiled
# `simulate_fleet` call sweeps the fault scenario across lanes.
#
#   * regional-blackout  -- one random cloud per lane loses ALL capacity
#     for a scheduled mid-run window (plus rare Markov flickers and task
#     failures): the recovery-time scenario.
#   * telemetry-brownout -- long carbon-feed dropouts (policy sees stale
#     intensities for ~10-20 slots at a stretch) plus partial capacity
#     brownouts: the staleness-guard scenario.
#   * flappy-uplink      -- WAN-only: clean alternate routes (odd link
#     indices in the congested-uplink topology) hard-flap on a Markov
#     chain; dirty primaries stay mostly up.


def regional_blackout(M: int, N: int, L, rng: np.random.Generator):
    from repro.faults import make_faults

    del M
    sched_start = np.zeros((N,), np.float32)
    sched_len = np.zeros((N,), np.float32)
    n_b = int(rng.integers(N))
    sched_start[n_b] = float(rng.uniform(40.0, 64.0))
    sched_len[n_b] = float(rng.uniform(24.0, 48.0))
    return make_faults(
        N, L,
        sched_start=sched_start, sched_len=sched_len,
        cloud_p_down=0.004, cloud_p_up=0.25,
        task_p_fail=0.03, backoff_max=6.0,
    )


def telemetry_brownout(M: int, N: int, L, rng: np.random.Generator):
    from repro.faults import make_faults

    del M, rng
    return make_faults(
        N, L,
        telem_p_down=0.10, telem_p_up=0.06,
        brown_p_start=0.04, brown_p_end=0.20, brown_floor=0.5,
    )


def flappy_uplink(M: int, N: int, L, rng: np.random.Generator):
    from repro.faults import make_faults

    del M, rng
    if L is None:
        raise ValueError(
            "flappy-uplink is a WAN fault scenario: build it on a "
            "network fleet (with_faults over build_network_fleet)"
        )
    alt = (np.arange(L) % 2 == 1)
    return make_faults(
        N, L,
        link_p_down=np.where(alt, 0.12, 0.02).astype(np.float32),
        link_p_up=np.full((L,), 0.35, np.float32),
        link_floor=np.zeros((L,), np.float32),
        task_p_fail=0.01,
    )


FAULT_SCENARIOS: Dict[str, Callable] = {
    "regional-blackout": regional_blackout,
    "telemetry-brownout": telemetry_brownout,
    "flappy-uplink": flappy_uplink,
}


def with_faults(
    fleet: FleetScenario, kind: str, seed: int = 0
) -> FleetScenario:
    """Attaches per-lane draws of a named fault scenario to a fleet
    (stacked on the `faults` axis). Lane j draws from
    default_rng((seed, 9, j)) -- disjoint from the instance streams
    `build_fleet` uses, so the same fleet is comparable with and
    without faults."""
    from repro.faults import stack_faults

    try:
        gen = FAULT_SCENARIOS[kind]
    except KeyError:
        raise KeyError(
            f"unknown fault scenario {kind!r}; registered: "
            f"{sorted(FAULT_SCENARIOS)}"
        ) from None
    M = fleet.arrival_amax.shape[1]
    N = fleet.spec.Pc.shape[1]
    L = None if fleet.graph is None else fleet.graph.bw.shape[-1]
    params = [
        gen(M, N, L, np.random.default_rng((seed, 9, j)))
        for j in range(fleet.F)
    ]
    return fleet._replace(faults=stack_faults(params))


# ---------------------------------------------------------------------------
# Deadline scenario registry (repro.deadlines). Each generator returns
# one lane's DeadlineParams from an instance-local RNG; `with_deadlines`
# stacks per-lane draws onto a fleet's `deadlines` axis (exactly the
# `with_faults` pattern, disjoint RNG stream (seed, 11, j)).
#
#   * tight-uniform -- every type gets a small finite deadline (2..6
#     extra slots) and a matching WaitAwhile window; shedding off: the
#     pure deadline-pressure scenario.
#   * mixed-slo     -- roughly half the types carry tight deadlines
#     (batch/interactive split); the rest are deadline-free. Windows
#     follow deadlines.
#   * shed-overload -- tight deadlines with admission control ON at
#     0.6 headroom: the graceful-degradation scenario (pair with the
#     "overload" arrival scenario above). 0.6 absorbs the per-type
#     service-allocation volatility under 1.8x overload -- at 0.8 the
#     EWMA rate estimate admits bursts the fill contest then starves,
#     leaving ~0.1% of admitted tasks to expire; the bench asserts
#     shedding holds misses at exactly zero.
#   * generous-slack -- deadlines wider than the waiting the benched
#     policies actually induce (48..59 extra slots on 64 rings; the
#     full-size LookaheadDPP tail age is ~37 slots): deferral stays
#     free everywhere, so a deadline-aware policy should recover the
#     unconstrained LookaheadDPP emission schedule while still
#     guaranteeing zero misses (the bench_deadline_pareto acceptance).


def tight_uniform(M: int, rng: np.random.Generator):
    from repro.deadlines import make_deadlines

    d = rng.integers(2, 7, M).astype(np.float32)
    return make_deadlines(M, deadline=d, window=d)


def mixed_slo(M: int, rng: np.random.Generator):
    from repro.deadlines import make_deadlines

    tight = rng.random(M) < 0.5
    d = np.where(
        tight, rng.integers(1, 5, M).astype(np.float32), np.inf
    ).astype(np.float32)
    return make_deadlines(M, deadline=d, window=d)


def shed_overload(M: int, rng: np.random.Generator):
    from repro.deadlines import make_deadlines

    d = rng.integers(2, 5, M).astype(np.float32)
    return make_deadlines(
        M, deadline=d, window=d, shed_on=1.0, headroom=0.6
    )


def generous_slack(M: int, rng: np.random.Generator):
    from repro.deadlines import make_deadlines

    d = rng.integers(48, 60, M).astype(np.float32)
    return make_deadlines(M, D=64, deadline=d, window=d)


DEADLINE_SCENARIOS: Dict[str, Callable] = {
    "tight-uniform": tight_uniform,
    "mixed-slo": mixed_slo,
    "shed-overload": shed_overload,
    "generous-slack": generous_slack,
}


def with_deadlines(
    fleet: FleetScenario, kind: str, seed: int = 0
) -> FleetScenario:
    """Attaches per-lane draws of a named deadline scenario to a fleet
    (stacked on the `deadlines` axis). Lane j draws from
    default_rng((seed, 11, j)) -- disjoint from the instance and fault
    streams, so the same fleet is comparable with and without the
    deadline layer."""
    from repro.deadlines import stack_deadlines

    try:
        gen = DEADLINE_SCENARIOS[kind]
    except KeyError:
        raise KeyError(
            f"unknown deadline scenario {kind!r}; registered: "
            f"{sorted(DEADLINE_SCENARIOS)}"
        ) from None
    M = fleet.arrival_amax.shape[1]
    params = [
        gen(M, np.random.default_rng((seed, 11, j)))
        for j in range(fleet.F)
    ]
    return fleet._replace(deadlines=stack_deadlines(params))


def build_fleet(
    kinds: Sequence[str] = tuple(SCENARIOS),
    per_kind: int = 16,
    M: int = 5,
    N: int = 5,
    Tc: int = 96,
    seed: int = 0,
) -> FleetScenario:
    """Stacks `per_kind` instances of every named scenario (F = len(kinds)
    * per_kind). Unknown names raise KeyError listing the registry."""
    instances = []
    for i, kind in enumerate(kinds):
        try:
            gen = SCENARIOS[kind]
        except KeyError:
            raise KeyError(
                f"unknown scenario {kind!r}; registered: "
                f"{sorted(SCENARIOS)}"
            ) from None
        for j in range(per_kind):
            rng = np.random.default_rng((seed, i, j))
            instances.append(gen(M, N, Tc, rng))
    return stack_scenarios(instances)
