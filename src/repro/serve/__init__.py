"""Online serving: the batch simulators' per-slot decision run as a
host loop around ONE donated-buffer compiled step, instrumented with
decision-latency percentiles, throughput and queue-age gauges
(DESIGN.md §Live observability)."""
from repro.serve.loop import (
    ServeReport,
    latency_percentiles,
    make_serve_step,
    serve_loop,
)

__all__ = [
    "ServeReport",
    "latency_percentiles",
    "make_serve_step",
    "serve_loop",
]
