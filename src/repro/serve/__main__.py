"""`python -m repro.serve` -- the serving-smoke CLI (see loop.main)."""
from repro.serve.loop import main

if __name__ == "__main__":
    main()
