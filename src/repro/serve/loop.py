"""Serving loop: streaming arrivals through one compiled step.

The batch simulators run T slots inside one `lax.scan`; a serving
deployment sees slots arrive in real time and must DECIDE each one as
it lands. This module promotes `examples/serve_batch.py`'s ad-hoc loop
into the library: `make_serve_step` compiles exactly one donated-buffer
step function (the SAME per-slot program as `core.simulator.simulate`'s
scan body, same PRNG stream splits -- so a served trajectory is bitwise
the batch trajectory), and `serve_loop` drives it from the host,
timing every decision.

Observability contract (ISSUE 9 / DESIGN.md §Live observability):

* decision latency -- wall time of one step call, device-synced via
  `block_until_ready`, recorded per slot; percentiles (p50/p95/p99,
  `np.percentile` linear interpolation) exclude the first `warmup`
  slots, where the call pays XLA compilation;
* throughput -- tasks/sec over the run's wall clock;
* queue age -- a host-side FIFO of (arrival slot, count) drained
  oldest-first by each slot's processing attempts: the age of the
  oldest unserved task, per slot, plus its max over the run;
* live export -- every `flush_every` slots the JSONL event log grows
  one `slot` event per slot and the Prometheus snapshot (counters,
  gauges, a latency histogram) is rewritten, so the run is watchable
  while it executes. `close` appends the terminal `summary` event --
  computed from the SAME per-slot arrays as the live events, so the
  live series always reconciles with the end-of-run `ServeReport`.

The clock is injectable (`clock=` callable returning seconds) and the
loop calls it in a fixed pattern -- once before the loop, twice per
slot (around the step), once after -- so tests drive it with a fake
and get deterministic histograms.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queueing import (
    Action,
    NetworkSpec,
    emissions,
    init_state,
)
from repro.core.queueing import step as queue_step

# Latency histogram buckets (microseconds), Prometheus-style with a
# terminal +Inf bucket appended by the exporter.
LATENCY_BUCKETS_US = (
    50.0, 100.0, 200.0, 500.0, 1e3, 2e3, 5e3, 1e4, 2e4, 5e4, 1e5, 1e6,
)


class ServeReport(NamedTuple):
    """End-of-run summary of a `serve_loop` drive. Scalar fields are
    what the terminal JSONL `summary` event carries; the arrays are the
    full per-slot series behind them."""

    slots: int
    warmup: int            # leading slots excluded from percentiles
    tasks_arrived: float
    tasks_dispatched: float
    tasks_processed: float
    total_emissions: float
    wall_s: float
    tasks_per_sec: float   # arrived tasks / wall_s
    p50_us: float          # decision-latency percentiles over
    p95_us: float          #   slots[warmup:]
    p99_us: float
    mean_us: float
    max_queue_age: int     # slots; oldest unserved task over the run
    latency_us: np.ndarray  # [slots] every decision, warmup included
    backlog: np.ndarray     # [slots] post-step Qe+Qc total
    queue_age: np.ndarray   # [slots] oldest unserved task's age
    # deadline-aware serving (zero / 0.0 when `deadlines` is off):
    missed_total: float = 0.0  # tasks expired past their deadline
    shed_total: float = 0.0    # arrivals rejected by admission control
    age_p50: float = 0.0       # queue-age percentiles over all slots --
    age_p95: float = 0.0       #   read against the configured deadline
    age_p99: float = 0.0       #   (the queue-age-vs-deadline export)
    age_over_deadline_frac: float = 0.0  # slots with age > min deadline


def latency_percentiles(lat_us) -> tuple:
    """(p50, p95, p99, mean) of a latency sample, `np.percentile`
    linear interpolation -- the one definition every consumer
    (ServeReport, live export, bench rows, perf_table) shares."""
    lat = np.asarray(lat_us, np.float64)
    p50, p95, p99 = (float(x) for x in
                     np.percentile(lat, [50.0, 95.0, 99.0]))
    return p50, p95, p99, float(lat.mean())


def make_serve_step(policy, spec: NetworkSpec, carbon_source,
                    arrival_source, key, deadlines=None) -> Callable:
    """Compiles the one serving step: `(state, t) -> (state', metrics)`
    with the state buffers DONATED (the loop never reuses the old
    state, so XLA may update queues in place).

    The body is `core.simulator.simulate`'s fault-free scan body with
    the same `jax.random.split(key, 3)` stream assignment, so driving
    it over t = 0..T-1 reproduces the batch trajectory bitwise.
    metrics = (emissions, arrived, dispatched, processed, backlog),
    all f32 scalars.

    With `deadlines` (a DeadlineParams) the carried state becomes the
    pair `(NetworkState, DeadlineState)`, the policy receives the
    slot's `deadline_view`, and metrics grows `(missed, shed)` -- the
    same deadline slot dynamics as the batch simulator, so the
    deadline-aware served trajectory is bitwise the batch one too.
    """
    k_carbon, k_arrive, k_policy = jax.random.split(key, 3)
    if deadlines is not None:
        from repro.deadlines.model import deadline_view, step_deadlines

    def step(state, t):
        if deadlines is not None:
            state, dstate = state
        Ce, Cc = carbon_source(t, k_carbon)
        a = arrival_source(t, k_arrive)
        k_t = jax.random.fold_in(k_policy, t)
        if deadlines is None:
            act: Action = policy(state, spec, Ce, Cc, a, k_t)
        else:
            act = policy(state, spec, Ce, Cc, a, k_t,
                         deadline_view=deadline_view(deadlines, dstate))
        C_t = emissions(spec, act, Ce, Cc)
        metrics = (
            C_t,
            jnp.sum(a),
            jnp.sum(act.d),
            jnp.sum(act.w),
        )
        if deadlines is None:
            nxt = queue_step(state, act, a)
            return nxt, metrics + (
                jnp.sum(nxt.Qe) + jnp.sum(nxt.Qc),
            )
        d_sum = jnp.sum(act.d, axis=1)
        dstate, admitted, expired, shed = step_deadlines(
            deadlines, dstate, d_sum, a
        )
        nxt = state._replace(
            Qe=jnp.maximum(state.Qe - d_sum, 0.0) + admitted - expired,
            Qc=jnp.maximum(state.Qc - act.w, 0.0) + act.d,
        )
        return (nxt, dstate), metrics + (
            jnp.sum(nxt.Qe) + jnp.sum(nxt.Qc),
            jnp.sum(expired),
            jnp.sum(shed),
        )

    return jax.jit(step, donate_argnums=0)


class _AgeFifo:
    """Host-side queue-age bookkeeping: arrivals enqueue (slot, count),
    processing attempts drain oldest-first; `age(t)` is the age of the
    oldest task still waiting. An approximation of per-task sojourn
    (the device queues are per-type/cloud, the FIFO is global) but an
    exact upper-bound gauge for "how stale is the oldest work"."""

    def __init__(self):
        self._fifo: list = []

    def update(self, t: int, arrived: float, processed: float) -> int:
        if arrived > 0:
            self._fifo.append([t, arrived])
        drain = processed
        while drain > 0 and self._fifo:
            head = self._fifo[0]
            take = min(head[1], drain)
            head[1] -= take
            drain -= take
            if head[1] <= 0:
                self._fifo.pop(0)
        return t - self._fifo[0][0] if self._fifo else 0


class ServeExporter:
    """Live Prometheus/JSONL writer for a serving run (the serve-side
    sibling of telemetry.export.FollowedRun). Buffers slot events and
    flushes every `flush_every` slots: appends the events to
    `<stem>.jsonl` and rewrites `<stem>.prom`. `close(report)` appends
    the terminal `summary` event built from the ServeReport, so
    `validate_jsonl` passes and live series reconcile with the summary
    by construction."""

    def __init__(self, outdir, stem: str = "serve",
                 flush_every: int = 16, warmup: int = 2):
        outdir = Path(outdir)
        outdir.mkdir(parents=True, exist_ok=True)
        self.paths = {
            "jsonl": outdir / f"{stem}.jsonl",
            "prometheus": outdir / f"{stem}.prom",
        }
        self.paths["jsonl"].write_text("")
        self.flush_every = flush_every
        self.warmup = warmup
        self._pending: list = []
        self._slots = 0
        self._lat: list = []       # non-warmup latencies so far
        self._totals = {"arrived": 0.0, "dispatched": 0.0,
                        "processed": 0.0, "emissions": 0.0,
                        "missed": 0.0, "shed": 0.0}
        self._last = {"backlog": 0.0, "queue_age": 0}

    def record(self, t: int, latency_us: float, arrived: float,
               dispatched: float, processed: float, backlog: float,
               queue_age: int, emissions_t: float,
               missed: float = 0.0, shed: float = 0.0) -> None:
        self._pending.append(json.dumps({
            "event": "slot", "kind": "serve", "t": t,
            "latency_us": latency_us, "arrived": arrived,
            "dispatched": dispatched, "processed": processed,
            "backlog": backlog, "queue_age": queue_age,
            "emissions": emissions_t, "warmup": t < self.warmup,
            "missed": missed, "shed": shed,
        }))
        self._slots += 1
        if t >= self.warmup:
            self._lat.append(latency_us)
        self._totals["arrived"] += arrived
        self._totals["dispatched"] += dispatched
        self._totals["processed"] += processed
        self._totals["emissions"] += emissions_t
        self._totals["missed"] += missed
        self._totals["shed"] += shed
        self._last = {"backlog": backlog, "queue_age": queue_age}
        if len(self._pending) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self._pending:
            with self.paths["jsonl"].open("a") as fh:
                fh.write("\n".join(self._pending) + "\n")
            self._pending = []
        self.paths["prometheus"].write_text(self._prometheus())

    def _prometheus(self) -> str:
        lines = []

        def emit(name, kind, help_, samples):
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in samples:
                lines.append(f"{name}{labels} {value:.10g}")

        emit("repro_serve_slots", "counter", "slots decided so far",
             [("", self._slots)])
        for k, v in self._totals.items():
            unit = "gCO2" if k == "emissions" else "tasks"
            help_ = {
                "missed": "tasks expired past their deadline (tasks)",
                "shed": "arrivals rejected by admission control (tasks)",
            }.get(k, f"running {k} over served slots ({unit})")
            emit(f"repro_serve_{k}_total", "counter", help_, [("", v)])
        emit("repro_serve_backlog", "gauge",
             "post-step backlog at the newest slot (tasks)",
             [("", self._last["backlog"])])
        emit("repro_serve_queue_age", "gauge",
             "oldest unserved task's age at the newest slot (slots)",
             [("", self._last["queue_age"])])
        if self._lat:
            lat = np.asarray(self._lat)
            p50, p95, p99, mean = latency_percentiles(lat)
            for q, v in (("p50", p50), ("p95", p95), ("p99", p99),
                         ("mean", mean)):
                emit(f"repro_serve_latency_{q}_us", "gauge",
                     f"decision latency {q} over non-warmup slots (us)",
                     [("", v)])
            name = "repro_serve_latency_us"
            lines.append(f"# HELP {name} decision latency (us)")
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for b in LATENCY_BUCKETS_US:
                cum = int((lat <= b).sum())
                lines.append(f'{name}_bucket{{le="{b:g}"}} {cum}')
            lines.append(f'{name}_bucket{{le="+Inf"}} {lat.size}')
            lines.append(f"{name}_sum {lat.sum():.10g}")
            lines.append(f"{name}_count {lat.size}")
        return "\n".join(lines) + "\n"

    def close(self, report: ServeReport) -> dict:
        self.flush()
        summary = {
            "event": "summary", "kind": "serve",
            "slots": report.slots, "warmup": report.warmup,
            "tasks_arrived": report.tasks_arrived,
            "tasks_dispatched": report.tasks_dispatched,
            "tasks_processed": report.tasks_processed,
            "total_emissions": report.total_emissions,
            "wall_s": report.wall_s,
            "tasks_per_sec": report.tasks_per_sec,
            "p50_us": report.p50_us, "p95_us": report.p95_us,
            "p99_us": report.p99_us, "mean_us": report.mean_us,
            "max_queue_age": report.max_queue_age,
            "missed_total": report.missed_total,
            "shed_total": report.shed_total,
            "age_p50": report.age_p50, "age_p95": report.age_p95,
            "age_p99": report.age_p99,
            "age_over_deadline_frac": report.age_over_deadline_frac,
        }
        with self.paths["jsonl"].open("a") as fh:
            fh.write(json.dumps(summary) + "\n")
        self.paths["prometheus"].write_text(self._prometheus())
        return self.paths


def serve_loop(policy, spec: NetworkSpec, carbon_source, arrival_source,
               T: int, key, *, warmup: int = 2, clock=None,
               outdir=None, stem: str = "serve",
               flush_every: int = 16, deadlines=None) -> ServeReport:
    """Drives `make_serve_step` for T slots from the host, timing every
    decision. `clock` defaults to `time.perf_counter`; inject a fake
    (called 2T + 2 times: loop start, before/after each step, loop end)
    for deterministic latency tests. `outdir` turns on live export via
    ServeExporter. Percentiles cover slots[warmup:] (slot 0 pays XLA
    compilation); `warmup` is clamped to T-1 so tiny runs still report.

    `deadlines` (a DeadlineParams) serves deadline-aware: per-slot
    expiries/sheds accumulate into the report and the live export, and
    the queue-age percentiles are read against the tightest configured
    deadline (`age_over_deadline_frac`).
    """
    if clock is None:
        clock = time.perf_counter
    warmup = max(0, min(warmup, T - 1))
    exporter = None
    if outdir is not None:
        exporter = ServeExporter(outdir, stem=stem,
                                 flush_every=flush_every, warmup=warmup)
    step = make_serve_step(policy, spec, carbon_source, arrival_source,
                           key, deadlines=deadlines)
    state = init_state(spec.M, spec.N)
    if deadlines is not None:
        from repro.deadlines.model import init_deadlines

        state = (state, init_deadlines(spec.M, deadlines.rings.shape[-1]))
    ages = _AgeFifo()
    lat = np.zeros(T)
    backlog = np.zeros(T)
    queue_age = np.zeros(T, np.int64)
    totals = {"arrived": 0.0, "dispatched": 0.0, "processed": 0.0,
              "emissions": 0.0, "missed": 0.0, "shed": 0.0}

    t_start = clock()
    for t in range(T):
        c0 = clock()
        state, metrics = step(state, jnp.int32(t))
        jax.block_until_ready(metrics)
        c1 = clock()
        lat[t] = (c1 - c0) * 1e6
        missed_t = shed_t = 0.0
        if deadlines is None:
            em_t, arrived, dispatched, processed, bl = (
                float(x) for x in metrics
            )
        else:
            (em_t, arrived, dispatched, processed, bl,
             missed_t, shed_t) = (float(x) for x in metrics)
        totals["arrived"] += arrived
        totals["dispatched"] += dispatched
        totals["processed"] += processed
        totals["emissions"] += em_t
        totals["missed"] += missed_t
        totals["shed"] += shed_t
        backlog[t] = bl
        # shed arrivals never enter the queue; missed tasks leave it by
        # expiry -- both must flow through the age FIFO or the gauge
        # reads phantom tasks (no-ops when the deadline layer is off)
        queue_age[t] = ages.update(t, arrived - shed_t,
                                   processed + missed_t)
        if exporter is not None:
            exporter.record(t, lat[t], arrived, dispatched, processed,
                            bl, int(queue_age[t]), em_t,
                            missed=missed_t, shed=shed_t)
    wall_s = clock() - t_start

    p50, p95, p99, mean = latency_percentiles(lat[warmup:])
    age_p50, age_p95, age_p99 = (
        float(x) for x in np.percentile(queue_age, [50.0, 95.0, 99.0])
    )
    over_frac = 0.0
    if deadlines is not None:
        d = np.asarray(deadlines.deadline, np.float64)
        finite = d[np.isfinite(d)]
        if finite.size:
            over_frac = float(np.mean(queue_age > finite.min()))
    report = ServeReport(
        slots=T,
        warmup=warmup,
        tasks_arrived=totals["arrived"],
        tasks_dispatched=totals["dispatched"],
        tasks_processed=totals["processed"],
        total_emissions=totals["emissions"],
        wall_s=wall_s,
        tasks_per_sec=totals["arrived"] / max(wall_s, 1e-12),
        p50_us=p50, p95_us=p95, p99_us=p99, mean_us=mean,
        max_queue_age=int(queue_age.max()),
        latency_us=lat,
        backlog=backlog,
        queue_age=queue_age,
        missed_total=totals["missed"],
        shed_total=totals["shed"],
        age_p50=age_p50, age_p95=age_p95, age_p99=age_p99,
        age_over_deadline_frac=over_frac,
    )
    if exporter is not None:
        exporter.close(report)
    return report


def _demo_spec(M: int, N: int, seed: int) -> NetworkSpec:
    rng = np.random.default_rng(seed)
    return NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=1e4,
        Pc=rng.uniform(1e3, 1e5, N).astype(np.float32),
    )


def main(argv=None) -> ServeReport:
    """CLI: `python -m repro.serve.loop` -- the CI serving-smoke entry.
    Serves a synthetic workload, prints the latency/throughput summary
    and (with `--outdir`) leaves live-exported Prometheus + JSONL
    behind for parse validation. REPRO_SMOKE=1 shrinks the instance;
    even smoke pushes >= 10^4 synthetic tasks through admission."""
    from repro.core import (
        CarbonIntensityPolicy,
        UKRegionalTraceSource,
        UniformArrivals,
    )

    smoke = os.environ.get("REPRO_SMOKE") == "1"
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=24 if smoke else 64)
    ap.add_argument("--types", type=int, default=16 if smoke else 64,
                    help="task types M")
    ap.add_argument("--clouds", type=int, default=4 if smoke else 8)
    ap.add_argument("--amax", type=int, default=100 if smoke else 300)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--flush-every", type=int, default=8)
    ap.add_argument("--outdir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=None,
                    help="serve deadline-aware: max extra waiting slots "
                         "per task before it expires (default: off)")
    ap.add_argument("--shed", action="store_true",
                    help="with --deadline: admission control sheds "
                         "arrivals projected capacity cannot clear")
    ap.add_argument("--headroom", type=float, default=0.9,
                    help="admission capacity factor for --shed")
    args = ap.parse_args(argv)

    deadlines = None
    policy = CarbonIntensityPolicy(V=0.05)
    if args.deadline is not None:
        from repro.deadlines import SlackThresholdPolicy, make_deadlines

        deadlines = make_deadlines(
            args.types, deadline=args.deadline,
            shed_on=1.0 if args.shed else 0.0, headroom=args.headroom,
        )
        policy = SlackThresholdPolicy(V=0.05)

    spec = _demo_spec(args.types, args.clouds, args.seed)
    report = serve_loop(
        policy,
        spec,
        UKRegionalTraceSource(N=args.clouds),
        UniformArrivals(M=args.types, amax=args.amax),
        args.slots,
        jax.random.PRNGKey(args.seed),
        warmup=args.warmup,
        outdir=args.outdir,
        flush_every=args.flush_every,
        deadlines=deadlines,
    )
    print(f"served {report.slots} slots "
          f"(M={args.types}, N={args.clouds}, amax={args.amax})")
    print(f"tasks arrived {report.tasks_arrived:.0f}, "
          f"processed {report.tasks_processed:.0f}, "
          f"throughput {report.tasks_per_sec:,.0f} tasks/sec")
    print(f"decision latency p50 {report.p50_us:.0f} us, "
          f"p95 {report.p95_us:.0f} us, p99 {report.p99_us:.0f} us "
          f"(warmup={report.warmup} excluded)")
    print(f"max queue age {report.max_queue_age} slots, "
          f"emissions {report.total_emissions:.3g} gCO2-eq")
    if deadlines is not None:
        print(f"queue age p50/p95/p99 {report.age_p50:.0f}/"
              f"{report.age_p95:.0f}/{report.age_p99:.0f} slots vs "
              f"deadline {args.deadline:g} "
              f"(over-deadline {report.age_over_deadline_frac:.1%}); "
              f"missed {report.missed_total:.0f}, "
              f"shed {report.shed_total:.0f}")
    if report.tasks_arrived < 1e4:
        raise SystemExit(
            f"serving smoke must cover >= 10^4 tasks, got "
            f"{report.tasks_arrived:.0f}"
        )
    return report


if __name__ == "__main__":
    main()
