"""Deadline/SLO layer: age-ringed queues, expiry, admission control,
load shedding, and deadline-aware policies (see deadlines/model.py for
the full contract and the infinite-deadline bitwise anchor)."""
from repro.deadlines.model import (
    DEFAULT_RINGS,
    DeadlineLedger,
    DeadlineParams,
    DeadlineState,
    DeadlineView,
    deadline_view,
    init_deadlines,
    make_deadlines,
    no_deadlines,
    stack_deadlines,
    step_deadlines,
)
from repro.deadlines.policy import (
    EDDPolicy,
    SlackThresholdPolicy,
    WaitAwhilePolicy,
)

__all__ = [
    "DEFAULT_RINGS",
    "DeadlineLedger",
    "DeadlineParams",
    "DeadlineState",
    "DeadlineView",
    "deadline_view",
    "init_deadlines",
    "make_deadlines",
    "no_deadlines",
    "stack_deadlines",
    "step_deadlines",
    "EDDPolicy",
    "SlackThresholdPolicy",
    "WaitAwhilePolicy",
]
