"""Deadline / SLO state for the queueing network: pure-JAX, scan-carried.

Tasks in the paper's model are fire-and-forget; this module gives every
task type a deadline (bounded tolerable waiting at the edge) and the
simulators an overload-robustness layer, all as scan-compatible JAX so
fleets sweep deadline scenarios across vmapped lanes:

  * age rings     -- the edge queue Qe[m] is shadowed by an age-bucketed
    decomposition `Qd[M, D]`: ring j holds the type-m tasks that have
    had j prior service opportunities. Dispatches drain oldest-first
    (the only order under which "deadline miss" is well-defined for a
    FIFO edge queue); unserved tasks age one ring per slot. The ring
    count D is carried as the SHAPE of the `rings` placeholder field,
    so it stays static under jit/vmap while every other parameter stays
    a sweepable array.
  * expiry        -- a task still queued after `deadline[m]` extra slots
    beyond its first service opportunity expires into an explicit
    per-slot `missed` counter (never silently dropped), keeping flow
    conservation exact in float32 integral counts:
      cum(arrived) = Qe + Qc [+ Qt] [+ retry]
                     + cum(processed) - cum(failed)
                     + cum(missed) + cum(shed)
  * admission control / load shedding -- with `shed_on`, arrivals that
    projected service capacity cannot clear inside their deadline are
    rejected at the door (counted in `shed`) instead of being admitted
    to expire later: the simulator degrades gracefully under overload
    rather than growing an unbounded queue of doomed work. Capacity is
    an EWMA `mu[m]` of observed dispatch rates, updated only on slots
    with queued work (idle slots carry no service-rate information --
    decaying on them would make a quiet system shed its next burst).

The infinite-deadline anchor: with `no_deadlines(...)` every deadline
and window is +inf and shedding is off, so expiry masks are all-false
(`expired` is an exact +0.0), the admission select returns the arrival
vector untouched, and the deadline-threaded simulators reduce to
bitwise identities of the pre-deadline ones (x + a - 0.0 == x + a in
IEEE float32) -- tests/test_deadlines.py asserts this on both score
backends, and `bench_deadline_pareto` re-asserts it before timing.

All carry leaves are float32 (the analysis.audit carry discipline);
the layer is fully deterministic -- no PRNG stream joins the scan.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.telemetry.profile import phase

Array = jax.Array

DEFAULT_RINGS = 32


class DeadlineParams(NamedTuple):
    """Deadline-layer parameters. A pytree of float32 arrays so fleets
    stack it on a leading axis and vmap lanes over deadline scenarios.

    `deadline[m]` counts EXTRA slots beyond the first service
    opportunity: a type with deadline 0 must be dispatched at its first
    opportunity or it expires; deadline d allows d+1 opportunities.
    +inf disables expiry for the type. Finite deadlines must be
    <= D - 1 (the top ring) -- `make_deadlines` validates this, since a
    deeper deadline than the ring buffer would silently never expire.
    """

    deadline: Array  # [M] max extra waiting slots (+inf = none)
    window: Array    # [M] WaitAwhile deferral window W (+inf = none)
    shed_on: Array   # []  1.0 = admission control active
    headroom: Array  # []  admission capacity factor (<1 sheds early)
    alpha: Array     # []  EWMA rate for the dispatch-rate estimate
    rings: Array     # [D] zeros; shape alone carries the ring count D

    @property
    def D(self) -> int:
        return self.rings.shape[-1]


class DeadlineState(NamedTuple):
    """Scan-carried deadline state (float32 per the audit carry rules)."""

    Qd: Array  # [M, D] age rings; sum over D mirrors Qe exactly
    mu: Array  # [M] EWMA of observed dispatch rate (admission input)


class DeadlineLedger(NamedTuple):
    """Per-run deadline accounting attached to a result's `.deadlines`
    field by the deadline-threaded simulators (None when the feature is
    off). Series cover all T slots in every record mode; `Qd` follows
    the record mode's state-trajectory length R (like Qe/Qc)."""

    missed: Array    # [T] tasks expired past their deadline per slot
    shed: Array      # [T] arrivals rejected by admission control
    admitted: Array  # [T] arrivals admitted to the edge queue
    Qd: Array        # [R, M, D] recorded age rings (post-step)

    @property
    def total_missed(self) -> Array:
        return jnp.sum(self.missed)

    @property
    def total_shed(self) -> Array:
        return jnp.sum(self.shed)


class DeadlineView(NamedTuple):
    """What one slot of deadline state exposes to the policy."""

    deadline: Array  # [M] per-type deadline (+inf = none)
    window: Array    # [M] per-type deferral window
    slack: Array     # [M] slots before the oldest queued task expires
    #                      (+inf when the queue is empty or no deadline)
    due: Array       # [M] 1.0 where slack == 0: last service chance
    ages: Array      # [M, D] the rings themselves


def no_deadlines(M: int, D: int = DEFAULT_RINGS) -> DeadlineParams:
    """Infinite deadlines/windows, shedding off: the bitwise anchor."""
    inf = jnp.full((M,), jnp.inf, jnp.float32)
    return DeadlineParams(
        deadline=inf,
        window=inf,
        shed_on=jnp.zeros((), jnp.float32),
        headroom=jnp.ones((), jnp.float32),
        alpha=jnp.asarray(0.2, jnp.float32),
        rings=jnp.zeros((D,), jnp.float32),
    )


def make_deadlines(M: int, D: int = DEFAULT_RINGS,
                   **overrides) -> DeadlineParams:
    """`no_deadlines` with per-field overrides, scalars broadcast to the
    field's shape -- the one constructor scenario builders and tests
    use so shapes/dtypes can't drift. Rejects finite deadlines deeper
    than the ring buffer (they would never expire)."""
    import numpy as np

    base = no_deadlines(M, D)
    bad = set(overrides) - (set(DeadlineParams._fields) - {"rings"})
    if bad:
        raise ValueError(f"unknown DeadlineParams fields: {sorted(bad)}")
    if "deadline" in overrides:
        d = np.asarray(overrides["deadline"], np.float32)
        finite = d[np.isfinite(d)]
        if finite.size and (finite.max() > D - 1 or finite.min() < 0):
            raise ValueError(
                f"finite deadlines must lie in [0, D-1] = [0, {D - 1}] "
                f"(got {finite.min():g}..{finite.max():g}); raise D to "
                "track older tasks"
            )
    cast = {
        k: jnp.broadcast_to(
            jnp.asarray(v, jnp.float32), getattr(base, k).shape
        )
        for k, v in overrides.items()
    }
    return base._replace(**cast)


def stack_deadlines(params: list) -> DeadlineParams:
    """Stacks per-lane DeadlineParams onto a leading fleet axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params)


def init_deadlines(M: int, D: int) -> DeadlineState:
    return DeadlineState(
        Qd=jnp.zeros((M, D), jnp.float32),
        mu=jnp.zeros((M,), jnp.float32),
    )


def deadline_view(params: DeadlineParams,
                  ds: DeadlineState) -> DeadlineView:
    """Builds the slot's policy-facing view: the slack of each type's
    OLDEST queued task (its deadline minus its current ring index), and
    the last-chance flag. Empty queues and infinite deadlines both read
    slack = +inf, so urgency math never divides by or multiplies an
    infinity (policies clip through it)."""
    D = params.rings.shape[-1]
    idx = jnp.arange(D, dtype=jnp.float32)
    occupied = ds.Qd > 0.0
    oldest = jnp.max(
        jnp.where(occupied, idx[None, :], -1.0), axis=-1
    )  # [M], -1 = empty
    slack = jnp.where(
        oldest >= 0.0,
        params.deadline - oldest,
        jnp.inf,
    )
    due = (slack <= 0.0).astype(jnp.float32)
    return DeadlineView(
        deadline=params.deadline,
        window=params.window,
        slack=slack,
        due=due,
        ages=ds.Qd,
    )


def step_deadlines(
    params: DeadlineParams,
    ds: DeadlineState,
    d_sum: Array,  # [M] tasks dispatched off the edge this slot
    a: Array,      # [M] arrivals (pre-admission)
) -> Tuple[DeadlineState, Array, Array, Array]:
    """One slot of deadline dynamics. Returns
    ``(next state, admitted [M], expired [M], shed [M])``; the caller's
    edge-queue update becomes ``max(Qe - d_sum, 0) + admitted - expired``
    (bitwise ``+ a`` under the `no_deadlines` anchor).

    Order inside the slot, mirroring the queue dynamics (departures
    bounded by the current queue, arrivals land after service):

      1. drain `d_sum` oldest-first across the rings (suffix-sum form:
         ring j gives up ``min(Qd[j], max(0, d - older_total))``);
      2. expire: post-drain rings at index >= deadline[m] empty into
         `expired` (all-false mask when deadline = +inf);
      3. age: survivors shift one ring up; the top ring is sticky (only
         ever populated under infinite deadlines -- `make_deadlines`
         rejects finite deadlines that deep);
      4. estimate: `mu` moves toward the observed dispatch rate, only
         on slots that had queued work to move;
      5. admit: with shedding on and a finite deadline, arrivals beyond
         ``floor(headroom * mu * (deadline+1)) - queued`` are shed --
         the work that projected capacity cannot clear inside its
         window. A cold estimator (mu == 0, service never observed)
         admits everything rather than shedding on no evidence.

    Every count stays integral (drains/expiry move integral ring
    contents; the admission cap is floored), so float32 conservation is
    exact -- the hypothesis property in
    tests/test_deadlines_properties.py.
    """
    with phase("deadline_step"):
        return _step_deadlines(params, ds, d_sum, a)


def _step_deadlines(params, ds, d_sum, a):
    D = params.rings.shape[-1]
    idx = jnp.arange(D, dtype=jnp.float32)

    total = jnp.sum(ds.Qd, axis=-1)  # [M] == Qe before this step
    d_clamped = jnp.minimum(d_sum, total)

    # oldest-first drain: ring j yields only after every older ring
    # (higher index) is empty. older[j] = sum of rings above j.
    older = (
        jnp.cumsum(ds.Qd[..., ::-1], axis=-1)[..., ::-1] - ds.Qd
    )
    taken = jnp.minimum(
        ds.Qd, jnp.maximum(d_clamped[:, None] - older, 0.0)
    )
    after = ds.Qd - taken

    # expiry: post-drain tasks at ring >= deadline miss their window.
    over = idx[None, :] >= params.deadline[:, None]  # [M, D] bool
    expired_rings = jnp.where(over, after, 0.0)
    expired = jnp.sum(expired_rings, axis=-1)  # [M]
    kept = after - expired_rings

    # aging: shift one ring up, sticky top ring.
    shifted = jnp.concatenate(
        [jnp.zeros_like(kept[..., :1]), kept[..., :-1]], axis=-1
    )
    shifted = shifted.at[..., -1].add(kept[..., -1])

    # dispatch-rate estimate: only slots with queued work carry signal.
    mu = jnp.where(
        total > 0.0,
        (1.0 - params.alpha) * ds.mu + params.alpha * d_clamped,
        ds.mu,
    )

    # admission: projected clearance inside the deadline window. Both
    # the deadline and the select are sanitized so `inf * 0` never
    # appears even in the unselected branch (checkify flags NaN
    # production inside where() arms); an infinite deadline admits
    # unconditionally through the +inf branch.
    queued = jnp.sum(shifted, axis=-1)
    finite = jnp.isfinite(params.deadline)
    d_safe = jnp.where(finite, params.deadline, 0.0)
    cap = jnp.where(
        (mu > 0.0) & finite,
        jnp.floor(
            jnp.maximum(
                params.headroom * mu * (d_safe + 1.0) - queued,
                0.0,
            )
        ),
        jnp.inf,
    )
    shed = jnp.where(
        params.shed_on > 0.0,
        jnp.maximum(a - cap, 0.0),
        jnp.zeros_like(a),
    )
    admitted = a - shed

    Qd = shifted.at[..., 0].add(admitted)
    return DeadlineState(Qd=Qd, mu=mu), admitted, expired, shed
