"""Deadline-aware policies: scan-compatible wrappers over the DPP score.

Three escalation styles, all driven by the per-slot `DeadlineView` the
deadline-threaded simulators pass as `deadline_view=`:

* SlackThresholdPolicy -- the mirror image of StalenessGuardPolicy:
  where the guard DECAYS V toward pure backpressure as the carbon
  signal goes stale, this escalates the *effective* V toward pure
  backpressure as slack -> 0. Implemented as score post-processing
  (subtracting the urgency share of the carbon term reproduces the
  score at V_eff = (1 - u) * V exactly), so both score backends and
  the single stacked greedy fill are reused untouched.
* EDDPolicy -- earliest-due-date: carbon-blind dispatch ordered by
  slack (most urgent type first), longest-queue cloud processing. The
  classical deadline baseline the carbon-aware policies must beat on
  emissions while matching on misses.
* WaitAwhilePolicy -- suspend/resume deferral: act only when the
  current slot ranks among the J cheapest slots of the forecast inside
  each task's admissible window min(W, slack); otherwise suspend by
  lifting scores to >= 0, which `greedy_fill` never takes. Due work
  overrides the gate (resume), so deferral never converts into a miss
  by itself.

All three degrade gracefully: with `deadline_view=None` (or no
forecast, for WaitAwhile) they ARE their parent policy, so the
infinite-deadline bitwise anchor extends to them.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.policies import (
    Action,
    LookaheadDPPPolicy,
    greedy_fill,
)

# Slack values are capped here before entering sort keys so that +inf
# (empty queue / no deadline) stays orderable and arithmetic-safe.
_SLACK_CAP = 1e6


@dataclasses.dataclass(frozen=True)
class SlackThresholdPolicy(LookaheadDPPPolicy):
    """Urgency-escalated drift-plus-penalty.

    Per-type urgency u = clip(1 - slack / slack_scale, 0, 1) shrinks
    the carbon term of the DPP score to its (1 - u) share -- exactly
    the score evaluated at V_eff = (1 - u) * V, so u = 1 (slack 0) is
    pure backpressure and u = 0 (slack >= slack_scale, or +inf) is the
    parent policy bit-for-bit (the subtraction is an exact -0.0).
    Types at their last service opportunity (`due`) additionally get a
    `due_push` subtracted from their dispatch score, putting them at
    the head of the greedy fill regardless of carbon.
    """

    slack_scale: float = 4.0
    due_push: float = 1e6

    def __call__(
        self,
        state,
        spec,
        Ce,
        Cc,
        arrivals,
        key=None,
        forecast=None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del fault_view
        if deadline_view is None:
            return super().__call__(
                state, spec, Ce, Cc, arrivals, key, forecast=forecast
            )
        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)
        Ce_eff, Cc_eff = self.effective_intensities(Ce, Cc, forecast)
        c, n1, b = self._scores(state, pe, pc, Ce_eff, Cc_eff, V)

        # clip() maps slack = +inf through 1 - inf = -inf to exactly
        # 0.0: no-deadline types never see a perturbed score.
        u = jnp.clip(
            1.0 - deadline_view.slack
            / jnp.asarray(self.slack_scale, jnp.float32),
            0.0,
            1.0,
        )
        b = b - u * (V * Ce_eff) * pe
        c = c - u[:, None] * (V * Cc_eff)[None, :] * pc
        b = b - deadline_view.due * jnp.asarray(self.due_push, jnp.float32)

        d_counts, w = self._fill_all(
            b, c, pe, pc, state.Qe, state.Qc, Pe, Pc
        )
        d = jnp.zeros_like(state.Qc).at[
            jnp.arange(spec.M), n1
        ].set(d_counts)
        return Action(d=d, w=w)


@dataclasses.dataclass(frozen=True)
class EDDPolicy:
    """Earliest-due-date baseline: carbon-blind, deadline-greedy.

    Edge: every type with waiting tasks dispatches in ascending-slack
    order (to its shortest cloud queue), as many as energy allows.
    Clouds: longest queues process first, as in QueueLengthPolicy.
    Without a deadline_view all occupied types tie (slack +inf), and
    the fill degrades to stable type-index order.
    """

    fill_chunk: int = 64

    def __call__(
        self,
        state,
        spec,
        Ce,
        Cc,
        arrivals,
        key=None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del Ce, Cc, arrivals, key, fault_view
        pe, pc, Pe, Pc = spec.as_arrays()
        n1 = jnp.argmin(state.Qc, axis=1)

        slack = (
            deadline_view.slack
            if deadline_view is not None
            else jnp.full_like(state.Qe, jnp.inf)
        )
        # Occupied types get a strictly negative key ordered by slack
        # (greedy_fill's contract: only negative keys are ever taken).
        edge = jnp.where(
            state.Qe > 0,
            jnp.minimum(slack, _SLACK_CAP) - (_SLACK_CAP + 1.0),
            1.0,
        )
        scores = jnp.concatenate(
            [edge[None, :], jnp.where(state.Qc > 0, -state.Qc, 1.0).T],
            axis=0,
        )
        counts = greedy_fill(
            scores,
            jnp.concatenate([pe[None, :], pc.T], axis=0),
            jnp.concatenate([state.Qe[None, :], state.Qc.T], axis=0),
            jnp.concatenate([jnp.reshape(Pe, (1,)), Pc], axis=0),
            stop_at_first_unfit=False,
            sort_key=scores,
            chunk=self.fill_chunk,
        )
        d = jnp.zeros_like(state.Qc).at[
            jnp.arange(spec.M), n1
        ].set(counts[0])
        return Action(d=d, w=counts[1:].T)


@dataclasses.dataclass(frozen=True)
class WaitAwhilePolicy(LookaheadDPPPolicy):
    """Suspend/resume deferral: act in the J cheapest admissible slots.

    Per type, the admissible window is min(window, slack) slots of the
    [H, N+1] forecast (a task may not defer past its deadline). The
    edge dispatch for type m suspends unless the CURRENT edge intensity
    ranks among the J cheapest admissible slots (strictly-cheaper
    count < J); cloud n's processing of type m suspends by the same
    rank test on cloud n's forecast column. Suspension lifts the score
    to max(score, 0) -- a non-negative score that `greedy_fill` never
    takes and that cannot trip its early stop. Due types resume
    unconditionally and get the `due_push` head-of-line boost, so
    deferral alone never expires work.

    Without a forecast or a deadline_view the gate has nothing to rank
    against and the policy IS its lookahead parent.
    """

    J: int = 2
    due_push: float = 1e6

    def __call__(
        self,
        state,
        spec,
        Ce,
        Cc,
        arrivals,
        key=None,
        forecast=None,
        fault_view=None,
        deadline_view=None,
    ) -> Action:
        del fault_view
        if deadline_view is None or forecast is None or self.H <= 0:
            return super().__call__(
                state, spec, Ce, Cc, arrivals, key, forecast=forecast
            )
        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)
        Ce_eff, Cc_eff = self.effective_intensities(Ce, Cc, forecast)
        c, n1, b = self._scores(state, pe, pc, Ce_eff, Cc_eff, V)

        f = forecast[: self.H].astype(jnp.float32)
        f = f.at[0].set(jnp.concatenate([Ce[None], Cc]))  # [H, N+1]
        wait = jnp.minimum(deadline_view.window, deadline_view.slack)
        h = jnp.arange(f.shape[0], dtype=jnp.float32)
        adm = h[None, :] <= wait[:, None]  # [M, H]; +inf -> all True

        # Edge gate: rank of now among admissible edge-intensity slots.
        fE = f[:, 0]
        rank_e = jnp.sum(
            (fE[None, :] < fE[0]) & adm, axis=1
        )  # [M]
        due = deadline_view.due > 0.0
        act_edge = (rank_e < self.J) | due
        b = jnp.where(act_edge, b, jnp.maximum(b, 0.0))
        b = b - deadline_view.due * jnp.asarray(self.due_push, jnp.float32)

        # Cloud gate: per (type, cloud) rank on that cloud's column.
        fC = f[:, 1:]  # [H, N]
        rank_c = jnp.sum(
            (fC[None, :, :] < fC[0][None, None, :]) & adm[:, :, None],
            axis=1,
        )  # [M, N]
        act_cloud = (rank_c < self.J) | due[:, None]
        c = jnp.where(act_cloud, c, jnp.maximum(c, 0.0))

        d_counts, w = self._fill_all(
            b, c, pe, pc, state.Qe, state.Qc, Pe, Pc
        )
        d = jnp.zeros_like(state.Qc).at[
            jnp.arange(spec.M), n1
        ].set(d_counts)
        return Action(d=d, w=w)


__all__ = [
    "SlackThresholdPolicy",
    "EDDPolicy",
    "WaitAwhilePolicy",
]
