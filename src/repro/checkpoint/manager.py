"""Checkpointing: atomic, resumable, async-capable.

Layout: <dir>/step_<N>/{arrays.npz, meta.json}. Writes go to a tmp dir
then os.replace (atomic on POSIX) so a crash mid-save never corrupts the
latest checkpoint. `CheckpointManager.save(..., blocking=False)` hands the
host copy to a writer thread (double-buffered) so the training loop
overlaps J/step with I/O -- the standard TPU-pod pattern where the
device->host transfer is the only synchronous part.

Restores return the exact pytree structure given as `like=` (dtypes and
shapes validated), plus the step and opaque JSON metadata (queue states,
RNG, data cursors).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_n: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, meta: Optional[Dict] = None,
             blocking: bool = True):
        """Snapshot `tree` at `step`. With blocking=False the device->host
        copy happens now but the file write runs on a background thread."""
        self.wait()  # one in-flight save at a time (double buffering)
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # sync d2h copy
        # numpy can't serialize ml_dtypes (bfloat16 etc.): store a uint
        # view + the true dtype in the manifest.
        dtypes = {}
        payload = {}
        for name, arr in zip(names, host_leaves):
            if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
                dtypes[name] = arr.dtype.name
                payload[name] = arr.view(
                    {2: np.uint16, 4: np.uint32, 1: np.uint8}[
                        arr.dtype.itemsize
                    ]
                )
            else:
                payload[name] = arr
        meta = dict(meta or {}, step=int(step), _dtypes=dtypes)

        def write():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **payload)
            (tmp / "meta.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=self._guard(write),
                                            daemon=True)
            self._thread.start()

    def _guard(self, fn):
        def run():
            try:
                fn()
            except BaseException as e:  # surfaced on next wait()
                self._error = e
        return run

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[Any, int, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "meta.json").read_text())
        dtypes = meta.get("_dtypes", {})
        with np.load(d / "arrays.npz") as z:
            names, leaves, treedef = _flatten_with_names(like)
            restored = []
            for name, ref in zip(names, leaves):
                arr = z[name]
                if name in dtypes:
                    import ml_dtypes
                    arr = arr.view(np.dtype(dtypes[name]))
                if tuple(arr.shape) != tuple(ref.shape):
                    raise ValueError(
                        f"ckpt shape mismatch at {name}: "
                        f"{arr.shape} vs {ref.shape}"
                    )
                restored.append(
                    jax.numpy.asarray(arr, dtype=ref.dtype)
                )
        tree = jax.tree_util.tree_unflatten(treedef, restored)
        return tree, int(meta["step"]), meta
