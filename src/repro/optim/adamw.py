"""AdamW from scratch (no optax dependency), ZeRO-friendly.

State = {m, v (fp32, sharded like params), step}. Parameters may be
bf16; the update is computed in fp32 and cast back. Global-norm clipping
and decoupled weight decay included. `scale_by_schedule` supplies cosine
LR with linear warmup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[Array], Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return AdamWState(
            m=zeros,
            v=jax.tree.map(lambda z: z.copy(), zeros),
            step=jnp.zeros((), jnp.int32),
        )

    def _lr(self, step: Array) -> Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(
        self, grads, state: AdamWState, params
    ) -> Tuple[Any, AdamWState, Dict[str, Array]]:
        # global-norm clip in fp32
        gsq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)
        )
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * delta
            return p2.astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, AdamWState(new_m, new_v, step), {
            "grad_norm": gnorm, "lr": lr,
        }


def cosine_schedule(
    peak_lr: float, warmup: int, total: int, floor: float = 0.1
) -> Callable[[Array], Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def make_train_step(model, opt: AdamW):
    """Returns train_step(params, opt_state, batch) -> (params', state',
    metrics). This is the function the train_4k dry-run cells lower."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(params)
        new_params, new_state, opt_metrics = opt.update(
            grads, opt_state, params
        )
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step
