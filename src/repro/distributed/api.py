"""Ambient sharding-hint API.

Model code calls `shard_hint(x, name)` at key activation sites. On a bare
CPU (tests, smoke runs) this is a no-op. The distributed launcher installs
a rule table {name -> PartitionSpec} via `activation_rules(...)`, after
which hints lower to with_sharding_constraint -- keeping model math 100%
layout-agnostic while the runtime owns placement.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def _rules() -> Optional[Dict[str, "jax.sharding.PartitionSpec"]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_rules(rules: Dict[str, "jax.sharding.PartitionSpec"],
                     mesh=None, dp_axes=None, ep_axis: str = "model"):
    """Installs activation-sharding rules and (optionally) the mesh
    context that enables explicitly-collective layers (shard_map MoE)."""
    prev = _rules()
    prev_mesh = mesh_context()
    _state.rules = rules
    _state.mesh = (mesh, tuple(dp_axes or ()), ep_axis) if mesh is not None \
        else None
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh


def mesh_context():
    """Returns (mesh, dp_axes, ep_axis) or None."""
    return getattr(_state, "mesh", None)


def shard_hint(x: jax.Array, name: str) -> jax.Array:
    rules = _rules()
    if not rules or name not in rules:
        return x
    sharding = rules[name]
    # Only rank must match; XLA pads non-divisible shardings.
    pspec = getattr(sharding, "spec", sharding)
    if len(pspec) > x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
