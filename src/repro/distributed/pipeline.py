"""GPipe-style pipeline parallelism skeleton (shard_map + ppermute).

Not enabled in the production mesh (DP x TP was sufficient to fit every
assigned architecture at 512 chips -- see EXPERIMENTS.md §Dry-run), but
shipped as the third parallelism dimension for >2-pod scale-out: stages
live on a 'stage' mesh axis, activations flow stage-to-stage with
collective_permute, and microbatches fill the bubble.

`pipeline_apply(stage_fn, stage_params, x, ...)` runs
    y = stage_fn(params_S-1, ... stage_fn(params_0, x))
for each of `n_micro` microbatches with the classic (S-1 + n_micro)-tick
schedule; bubble fraction = (S-1)/(S-1+n_micro).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def pipeline_apply(
    stage_fn: Callable,        # (stage_params, x_mb) -> y_mb
    stage_params,              # pytree, leaves stacked [n_stages, ...]
    x: Array,                  # [n_micro, mb, ...] microbatched input
    mesh: Mesh,
    axis: str = "stage",
) -> Array:
    """Runs the staged computation over all microbatches; returns
    [n_micro, mb, ...] outputs (as produced by the LAST stage)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert x.shape[0] == n_micro

    def body(params, xs):
        # params: this stage's slice (leading stage dim of size 1 kept by
        # shard_map -> squeeze); xs: the full microbatch stream, present
        # on every stage (only stage 0 consumes it).
        params = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        n_ticks = n_stages - 1 + n_micro
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            buf, outs = carry  # buf: activation entering this stage
            # stage 0 injects microbatch t (when in range)
            mb = jnp.where(
                t < n_micro,
                jax.lax.dynamic_index_in_dim(
                    xs, jnp.clip(t, 0, n_micro - 1), keepdims=False
                ),
                jnp.zeros(mb_shape, xs.dtype),
            )
            inp = jnp.where(idx == 0, mb, buf)
            out = stage_fn(params, inp)
            # last stage writes its result for microbatch t-(S-1)
            mb_id = t - (n_stages - 1)
            outs = jax.lax.cond(
                (idx == n_stages - 1) & (mb_id >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.clip(mb_id, 0, n_micro - 1), 0
                ),
                lambda o: o,
                outs,
            )
            # shift activations downstream: stage i -> i+1
            nxt = jax.lax.ppermute(
                out, axis,
                [(i, i + 1) for i in range(n_stages - 1)],
            )
            return (nxt, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(n_ticks)
        )
        # only the last stage holds real outputs: psum broadcasts them
        # (all other stages contribute zeros)
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis,
        )
        return outs

    from repro.distributed.compat import shard_map

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),    # params sharded by stage; x replicated
        out_specs=P(),               # outputs replicated after the psum
        check_vma=False,
    )
    return fn(stage_params, x)


def pipeline_reference(stage_fn, stage_params, x):
    """Sequential oracle: apply all stages to every microbatch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def apply_all(x_mb):
        for s in range(n_stages):
            p_s = jax.tree.map(lambda a: a[s], stage_params)
            x_mb = stage_fn(p_s, x_mb)
        return x_mb

    return jax.vmap(apply_all)(x)
