"""JAX distributed API compatibility shims (same spirit as
kernels/compat.py for Pallas).

``shard_map`` graduated from ``jax.experimental.shard_map`` to a
top-level ``jax.shard_map`` and renamed its replication-check kwarg
``check_rep`` -> ``check_vma`` along the way. Feature-detect once so
the expert-parallel MoE, the pipeline skeleton, and the runtime tests
work across the installed range.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Dispatches to jax.shard_map (new) or experimental.shard_map (old),
    translating check_vma to the old check_rep spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


__all__ = ["shard_map"]
