"""Per-architecture sharding rules: FSDP('data') x TP/EP('model'),
pod axis folded into data parallelism.

`shardings_for(mesh, tree, kind)` walks any param / optimizer / batch /
cache pytree and assigns a NamedSharding per leaf from name+rank rules,
with divisibility-aware fallbacks (a mesh axis is only used on a dim it
divides; otherwise the dim stays replicated and the fact is recorded for
the roofline notes).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


class RuleEngine:
    """Name+rank -> PartitionSpec with divisibility fallback."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.dp = dp_axes(mesh)
        self.fallbacks = []  # (path, dim, axis) that had to be replicated

    def _fit(self, spec_entry, size: int, path: str, dim: int):
        if spec_entry is None:
            return None
        if size % axis_size(self.mesh, spec_entry) == 0:
            return spec_entry
        self.fallbacks.append((path, dim, spec_entry))
        return None

    def spec(self, path: str, entries, shape) -> NamedSharding:
        """entries: desired axis per trailing dim (aligned to the right);
        leading (layer-stack) dims stay unsharded."""
        n = len(shape)
        k = len(entries)
        full = [None] * (n - k) + [
            self._fit(e, shape[(n - k) + i], path, (n - k) + i)
            for i, e in enumerate(entries)
        ]
        return NamedSharding(self.mesh, P(*full))


# ---- parameter rules, keyed by leaf name -------------------------------
def _param_entries(name: str, dp, rank: int):
    tp = "model"
    table = {
        # embeddings
        "embed": (tp, dp),        # [V, D]
        "unembed": (dp, tp),      # [D, V]
        # attention
        "wq": (dp, tp, None),     # [D, H, hd]
        "wk": (dp, tp, None),
        "wv": (dp, tp, None),
        "wo": (tp, None, dp),     # [H, hd, D]
        "bq": (tp, None),
        "bk": (tp, None),
        "bv": (tp, None),
        # mlp
        "w_in": (dp, tp),         # [D, F]
        "w_gate": (dp, tp),
        "w_out": (tp, dp),        # [F, D]
        # moe (rank-4 handled below): router [D, E]
        "router": (dp, None),
        # mamba
        "in_proj": (dp, tp),      # [D, 2di+2ds+nh]
        "out_proj": (tp, dp),     # [di, D]
        "conv_w": (None, tp),     # [W, C]
        "conv_b": (tp,),
        "dt_bias": (None,),
        "A_log": (None,),
        "D": (None,),
        # norms
        "scale": (None,),
        "bias": (None,),
    }
    entries = table.get(name)
    if entries is None:
        return (None,) * min(rank, 1)
    # MoE expert tensors: w_in/w_gate [E, D, F], w_out [E, F, D]
    return entries


def param_shardings(mesh: Mesh, params, cfg=None):
    """NamedShardings for a parameter (or same-structure m/v) pytree."""
    eng = RuleEngine(mesh)
    dp = eng.dp if len(eng.dp) > 1 else (eng.dp[0] if eng.dp else None)

    def assign(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        shape = leaf.shape
        entries = _param_entries(name, dp, len(shape))
        # expert-stacked MLP weights under a "moe" subtree carry a leading
        # expert dim: EP on E ('model'), FSDP on the d_model dim.
        if name in ("w_in", "w_gate", "w_out") and any(
            getattr(e, "key", None) == "moe" for e in path
        ):
            if name in ("w_in", "w_gate"):   # [E, D, F]
                entries = ("model", dp, None)
            else:                            # [E, F, D]
                entries = ("model", None, dp)
        return eng.spec(jax.tree_util.keystr(path), entries, shape)

    out = jax.tree_util.tree_map_with_path(assign, params)
    return out, eng.fallbacks


def batch_shardings(mesh: Mesh, batch_specs):
    """Shard batches on the batch dim over all DP axes; sequence dims on
    'model' for long-sequence inputs (frames/patches keep seq replicated
    -- they feed layernorm'd prefixes)."""
    eng = RuleEngine(mesh)
    dp = eng.dp if len(eng.dp) > 1 else (eng.dp[0] if eng.dp else None)

    def assign(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = leaf.shape
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        entries = [dp] + [None] * (leaf.ndim - 1)
        return eng.spec(name, tuple(entries), shape)

    return jax.tree_util.tree_map_with_path(assign, batch_specs)


def cache_shardings(mesh: Mesh, cache_specs, cfg):
    """KV caches: batch over DP; kv-heads over 'model' when divisible,
    else the sequence dim over 'model' (flash-decode style partial
    softmax). SSM states: heads over 'model'."""
    eng = RuleEngine(mesh)
    dp = eng.dp if len(eng.dp) > 1 else (eng.dp[0] if eng.dp else None)
    tp_size = axis_size(mesh, "model")
    kv_div = cfg.n_kv_heads % tp_size == 0 if cfg.n_kv_heads else False

    def assign(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        shape = leaf.shape
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "ck", "cv"):
            # [L, B, S, K, hd]
            if kv_div:
                entries = (None, dp, None, "model", None)
            else:
                entries = (None, dp, "model", None, None)
            return eng.spec(name, entries, shape)
        if name == "ssm":
            # [L, B, H, N, P] or [nb, ni, B, H, N, P]
            entries = [None] * (leaf.ndim - 4) + [dp, "model", None, None]
            return eng.spec(name, tuple(entries), shape)
        if name == "conv":
            # [L, B, W-1, C] or [nb, ni, B, W-1, C]
            entries = [None] * (leaf.ndim - 3) + [dp, None, "model"]
            return eng.spec(name, tuple(entries), shape)
        entries = [dp] + [None] * (leaf.ndim - 1)
        return eng.spec(name or "cache", tuple(entries), shape)

    return jax.tree_util.tree_map_with_path(assign, cache_specs)


def activation_rule_table(mesh: Mesh, cfg,
                          seq_parallel: bool = False
                          ) -> Dict[str, NamedSharding]:
    """Hints installed around lowering (see distributed/api.py).

    seq_parallel=True keeps the residual stream sequence-sharded over the
    'model' axis end to end (Megatron-SP style): attention gathers only
    K/V (cheap under GQA), the attention-output psum disappears, and the
    MoE's token layout needs no reshard. Found in §Perf iteration 2 to cut
    the collective term by >40% on MoE train cells; enabled per-cell via
    dryrun --seq-parallel.
    """
    eng = RuleEngine(mesh)
    dp = eng.dp if len(eng.dp) > 1 else (eng.dp[0] if eng.dp else None)
    tp = "model"
    tp_size = axis_size(mesh, tp)

    def ns(*entries):
        return NamedSharding(mesh, P(*entries))

    if seq_parallel:
        rules = {
            "act_btd": ns(dp, tp, None),
            "act_ffn": ns(dp, tp, None),
            "logits": ns(dp, tp, None),
        }
    else:
        rules = {
            "act_btd": ns(dp, None, None),
            "act_ffn": ns(dp, None, tp),
            "logits": ns(dp, None, tp),
        }
        if cfg.n_heads and cfg.n_heads % tp_size == 0:
            rules["act_heads"] = ns(dp, None, tp, None)
    if cfg.n_experts:
        rules["moe_buf"] = ns(tp, None, None)  # EP on expert dim
    return rules
