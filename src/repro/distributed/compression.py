"""Gradient compression: int8 quantization with error feedback.

Beyond-paper distributed-optimization feature: cross-pod gradient
all-reduce traffic dominates the multi-pod collective term (see
EXPERIMENTS.md §Roofline), and the inter-pod links are the slowest hop.
Error-feedback int8 (Seide et al.-style) cuts the payload 4x vs fp32 /
2x vs bf16 while the residual accumulator keeps the *time-averaged*
gradient unbiased -- SGD/Adam convergence is preserved (1-bit Adam / EF21
literature), validated numerically in tests/test_compression.py.

`compress(g, state)` / `decompress(q)` are pure and usable inside
shard_map collectives:

    q, s = quantize(g + state.residual)
    q_sum = jax.lax.psum(dequantize(q, s), 'pod')   # wire: int8 + scale
    state.residual = (g + state.residual) - dequantize(q, s)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    residual: Any  # pytree like grads (fp32)


def init_ef_state(grads_like) -> EFState:
    return EFState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def quantize(x: Array) -> Tuple[Array, Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, state: EFState):
    """Error-feedback compress a grads pytree.

    Returns (quantized pytree of (q, scale), new EFState). The caller
    reduces the dequantized values (or ships (q, scale) over the wire)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize(x)
        new_r = x - dequantize(q, s)
        return (q, s), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = tdef.unflatten([p[0] for p in pairs])
    new_state = EFState(residual=tdef.unflatten([p[1] for p in pairs]))
    return qtree, new_state


def ef_decompress_tree(qtree, grads_like):
    flat_q, tdef = jax.tree.flatten(
        qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
    )
    return tdef.unflatten([dequantize(q, s) for q, s in flat_q])


def compressed_psum_grads(grads, state: EFState, axis_name: str):
    """Drop-in psum replacement for use inside shard_map: int8 payload on
    the wire, error feedback locally. Dequantize-then-psum is numerically
    identical to psum-of-int8 x shared scale when scales agree; per-device
    scales make this an approximation whose error lands in the residual."""
    qtree, new_state = ef_compress_tree(grads, state)
    deq = ef_decompress_tree(qtree, grads)
    summed = jax.tree.map(lambda x: jax.lax.psum(x, axis_name), deq)
    return summed, new_state
