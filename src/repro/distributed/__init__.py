from repro.distributed.api import activation_rules, shard_hint
