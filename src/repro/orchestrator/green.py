"""GreenOrchestrator: the paper's carbon-intensity scheduler as the
control plane for real training jobs.

Mapping (paper -> runtime):
  task type m   -> a TrainJob (architecture + data stream + optimizer)
  cloud n       -> a Cloud execution slot (mesh slice / pod; here: the
                   local device, with per-cloud speed to emulate
                   heterogeneity and stragglers)
  d[m,n]        -> staging a task's data/weights to cloud n (edge energy)
  w[m,n]        -> running `steps_per_task` real train steps of job m
  C(t)          -> measured-FLOPs energy proxy x live carbon intensity

Fault tolerance:
  * checkpoint every `ckpt_every` slots: every job's params/opt state +
    virtual queues + emission accumulators (atomic, async-capable)
  * crash-restart: `resume()` reloads the latest checkpoint; the run is
    bit-deterministic afterwards (carbon/arrivals are pure in t)
  * straggler mitigation: per-slot deadline; a slow cloud's *effective*
    energy budget Pc[n] shrinks proportionally to its measured slowdown,
    so the drift-plus-penalty policy automatically routes work away --
    the paper's queueing model absorbs stragglers with no special-casing
  * elasticity: clouds can leave/join (alive mask -> Pc[n]=0 while down);
    queued work re-routes by the same mechanism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.queueing import Action, NetworkSpec, NetworkState, init_state, step as queue_step
from repro.core.policies import CarbonIntensityPolicy

Array = jax.Array


@dataclasses.dataclass
class TrainJob:
    """One task type: a live training run."""

    name: str
    model: object
    train_step: Callable  # (params, opt_state, batch) -> (p', o', metrics)
    batch_fn: Callable    # step -> batch
    params: object
    opt_state: object
    steps_per_task: int = 2
    step: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)

    def run_task(self) -> Dict[str, float]:
        for _ in range(self.steps_per_task):
            batch = self.batch_fn(self.step)
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            self.step += 1
        loss = float(metrics["loss"])
        self.losses.append(loss)
        return {"loss": loss, "step": self.step}

    def flops_per_task(self, tokens_per_step: int) -> float:
        return 6.0 * self.model.cfg.active_params() * tokens_per_step * \
            self.steps_per_task


@dataclasses.dataclass
class Cloud:
    name: str
    alive: bool = True
    speed: float = 1.0          # emulated relative throughput
    measured_slowdown: float = 1.0  # EWMA of observed / expected time


class GreenOrchestrator:
    def __init__(
        self,
        jobs: List[TrainJob],
        clouds: List[Cloud],
        spec: NetworkSpec,
        carbon_source: Callable,
        arrival_fn: Callable,          # t -> np.ndarray [M]
        policy=None,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 5,
        max_tasks_per_slot: int = 4,   # wall-clock cap per (cloud, slot)
        slot_deadline_s: Optional[float] = None,
        carbon_key: Optional[Array] = None,
    ):
        assert len(jobs) == spec.M and len(clouds) == spec.N
        self.jobs = jobs
        self.clouds = clouds
        self.spec = spec
        self.carbon = carbon_source
        self.arrivals = arrival_fn
        self.policy = policy or CarbonIntensityPolicy(V=0.05)
        self.state = init_state(spec.M, spec.N)
        self.t = 0
        self.cum_emissions = 0.0
        self.cum_emissions_trace: List[float] = []
        self.executed_tasks = 0
        self.dropped_slots = 0
        self.max_tasks_per_slot = max_tasks_per_slot
        self.slot_deadline_s = slot_deadline_s
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self._carbon_key = carbon_key if carbon_key is not None else \
            jax.random.PRNGKey(0)

    # ------------------------------------------------------------ state --
    def _snapshot_tree(self):
        return {
            "queues": {"Qe": self.state.Qe, "Qc": self.state.Qc},
            "jobs": {
                j.name: {"params": j.params, "opt": j.opt_state}
                for j in self.jobs
            },
        }

    def checkpoint(self, blocking: bool = True):
        if not self.ckpt:
            return
        meta = {
            "t": self.t,
            "cum_emissions": self.cum_emissions,
            "executed_tasks": self.executed_tasks,
            "job_steps": {j.name: j.step for j in self.jobs},
            "cloud_alive": [c.alive for c in self.clouds],
        }
        self.ckpt.save(self.t, self._snapshot_tree(), meta, blocking=blocking)

    def resume(self) -> bool:
        if not self.ckpt or self.ckpt.latest_step() is None:
            return False
        tree, t, meta = self.ckpt.restore(self._snapshot_tree())
        self.state = NetworkState(
            Qe=tree["queues"]["Qe"], Qc=tree["queues"]["Qc"]
        )
        for j in self.jobs:
            j.params = tree["jobs"][j.name]["params"]
            j.opt_state = tree["jobs"][j.name]["opt"]
            j.step = int(meta["job_steps"][j.name])
        for c, alive in zip(self.clouds, meta["cloud_alive"]):
            c.alive = bool(alive)
        self.t = int(meta["t"])
        self.cum_emissions = float(meta["cum_emissions"])
        self.executed_tasks = int(meta["executed_tasks"])
        return True

    # -------------------------------------------------------- elasticity --
    def fail_cloud(self, n: int):
        self.clouds[n].alive = False

    def join_cloud(self, n: int):
        self.clouds[n].alive = True
        self.clouds[n].measured_slowdown = 1.0

    def _effective_spec(self) -> NetworkSpec:
        """Straggler/elastic-aware capacities: dead -> 0, slow -> shrunk."""
        Pc = np.asarray(self.spec.Pc, np.float32).copy()
        for n, c in enumerate(self.clouds):
            if not c.alive:
                Pc[n] = 0.0
            elif c.measured_slowdown > 1.05:
                Pc[n] = Pc[n] / c.measured_slowdown
        return dataclasses.replace(self.spec, Pc=Pc)

    @staticmethod
    def _slowdown(elapsed: float, deadline: float, expected: float) -> float:
        """Observed/expected slot time ratio for the straggler EWMA.

        A cloud that ran `expected` task-equivalents is on schedule when
        elapsed ~= deadline * expected, so the denominator scales with
        the expected count (clamped below at one task so an almost-idle
        slot cannot divide by ~0 and explode the estimate).
        """
        return elapsed / (deadline * max(expected, 1.0))

    # -------------------------------------------------------------- run --
    def run_slot(self) -> Dict[str, float]:
        import jax.numpy as jnp

        t = self.t
        Ce, Cc = self.carbon(jnp.asarray(t), self._carbon_key)
        a = self.arrivals(t)
        eff_spec = self._effective_spec()
        act = self.policy(
            self.state, eff_spec, Ce, jnp.asarray(Cc), jnp.asarray(a), None
        )
        d = np.asarray(act.d)
        w = np.asarray(act.w).copy()

        # execute processing: real train steps, capped per slot
        slot_metrics = {}
        pe, pc = np.asarray(self.spec.pe), np.asarray(self.spec.pc)
        for n, cloud in enumerate(self.clouds):
            if not cloud.alive:
                w[:, n] = 0
                continue
            budget = self.max_tasks_per_slot
            t_start = time.monotonic()
            expected = 0.0
            for m in range(self.spec.M):
                todo = int(min(w[m, n], budget))
                done = 0
                for _ in range(todo):
                    if (self.slot_deadline_s is not None and
                            time.monotonic() - t_start >
                            self.slot_deadline_s):
                        break
                    metrics = self.jobs[m].run_task()
                    expected += 1.0
                    done += 1
                    self.executed_tasks += 1
                    slot_metrics[f"loss/{self.jobs[m].name}"] = \
                        metrics["loss"]
                budget -= done
                w[m, n] = done  # only what actually ran leaves the queue
            elapsed = time.monotonic() - t_start
            if self.slot_deadline_s is not None and expected > 0:
                # emulated heterogeneity: a declared-slow cloud observes
                # inflated wall time (tasks run at real local speed, so
                # the emulation must scale elapsed, not the expectation)
                slowdown = self._slowdown(
                    elapsed / max(cloud.speed, 1e-3),
                    self.slot_deadline_s, expected,
                )
                cloud.measured_slowdown = (
                    0.7 * cloud.measured_slowdown + 0.3 * max(slowdown, 1.0)
                )

        # emissions accounting, eq. (5), with the *executed* action
        edge_e = float((d * pe[:, None]).sum())
        cloud_e = (w * pc).sum(axis=0)
        C_t = float(Ce) * edge_e + float(np.dot(np.asarray(Cc), cloud_e))
        self.cum_emissions += C_t
        self.cum_emissions_trace.append(self.cum_emissions)

        self.state = queue_step(
            self.state,
            Action(d=jax.numpy.asarray(d), w=jax.numpy.asarray(w)),
            jax.numpy.asarray(a),
        )
        self.t += 1
        if self.ckpt and self.t % self.ckpt_every == 0:
            self.checkpoint(blocking=False)
        return dict(
            slot_metrics,
            emissions=C_t,
            backlog=float(self.state.Qe.sum() + self.state.Qc.sum()),
            executed=self.executed_tasks,
        )

    def run(self, n_slots: int, fail_at: Optional[Dict[int, int]] = None):
        """fail_at: {slot: cloud} simulated cloud failures."""
        history = []
        fail_at = fail_at or {}
        for _ in range(n_slots):
            if self.t in fail_at:
                self.fail_cloud(fail_at[self.t])
            history.append(self.run_slot())
        if self.ckpt:
            self.ckpt.wait()
        return history
