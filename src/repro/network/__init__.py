"""Carbon-aware WAN transfer subsystem (beyond-paper).

The paper's model dispatches tasks straight into cloud queues; this
package inserts the wide-area network in between: a `LinkGraph` of
bandwidth-capped, carbon-priced routes, an in-flight transfer queue
`Qt [M, L]` threaded through the simulator's scan carry, and a
`NetworkAwareDPPPolicy` that ranks (task-type, route, cloud) triples by
queue drift plus V-weighted end-to-end carbon. See DESIGN.md
§Carbon-aware WAN transfer subsystem; regression anchor: on
`direct_graph` the whole stack is bit-identical to the link-free
simulator under `CarbonIntensityPolicy`.
"""
from repro.network.graph import (
    LinkGraph,
    congested_uplink_graph,
    direct_graph,
    make_graph,
    multi_region_wan_graph,
    stack_graphs,
    star_graph,
)
from repro.network.policy import NetworkAwareDPPPolicy, StaticRoutePolicy
from repro.network.sim import NetSimResult, simulate_network
from repro.network.transfer import (
    LinkState,
    NetAction,
    init_links,
    land_in_clouds,
    network_emissions,
    step_links,
    transfer_energy,
)

__all__ = [
    "LinkGraph",
    "LinkState",
    "NetAction",
    "NetSimResult",
    "NetworkAwareDPPPolicy",
    "StaticRoutePolicy",
    "congested_uplink_graph",
    "direct_graph",
    "init_links",
    "land_in_clouds",
    "make_graph",
    "multi_region_wan_graph",
    "network_emissions",
    "simulate_network",
    "stack_graphs",
    "star_graph",
    "step_links",
    "transfer_energy",
]
