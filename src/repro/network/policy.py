"""Route-aware scheduling policies over a LinkGraph.

* NetworkAwareDPPPolicy -- the drift-plus-penalty dispatch extended to
  the route lattice: instead of "each type to its emptiest cloud", each
  type goes to the (route, cloud) pair minimizing

      rc[m,l] = V*Ct[l]*pt[m,l]                (transfer carbon, route l)
              + route_compute_weight * V*Cc[dest[l]]*pc[m,dest[l]]
              + Qt[m,l] + Qc[m,dest[l]]        (in-flight + dest drift)

  with the dispatch score b[m] = V*Ce*pe[m] + min_l rc[m,l] - Qe[m]
  feeding the identical greedy energy fill as Algorithm 1. The Qt term
  is what makes the policy congestion-aware: a saturated route's
  backlog prices it out, no explicit bandwidth constraint needed in the
  score pass. Subclassing LookaheadDPPPolicy means an [H, N+1] forecast
  (PR 3) deferral-penalizes the whole intensity row -- link carbon
  regions included -- before any score is computed; H=1 (the default)
  is exactly myopic.

  On the degenerate `direct_graph` (one infinite-bandwidth,
  zero-transfer-carbon link per cloud) rc collapses bitwise onto the
  Qc column sweep, so actions are bit-identical to CarbonIntensityPolicy
  on both score backends -- the subsystem's regression anchor.

* StaticRoutePolicy -- transfer-blind adapter: runs any edge->cloud
  policy unchanged and ships its dispatches down the graph's primary
  routes, ignoring Qt and link carbon. The baseline the route-aware
  policy must beat on congested topologies (bench_network_routing).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.policies import LookaheadDPPPolicy
from repro.core.queueing import NetworkSpec, NetworkState
from repro.network.graph import LinkGraph
from repro.network.transfer import NetAction
from repro.telemetry.profile import phase

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NetworkAwareDPPPolicy(LookaheadDPPPolicy):
    """Joint route+schedule DPP. Inherits V / greedy-fill options /
    score_backend from CarbonIntensityPolicy and the receding-horizon
    machinery (H, discount, defer_weight) from LookaheadDPPPolicy;
    H defaults to 1 here (myopic) so the policy only plans ahead when
    explicitly configured with a horizon AND a forecaster.

    route_compute_weight anticipates the destination's compute carbon at
    dispatch time (end-to-end ranking). It defaults to 0 because strict
    DPP semantics charge compute carbon when the cloud processes (the
    cloud-side scores already see it) -- a nonzero weight is a bias that
    pays off when destination queues are short-lived; it breaks the
    degenerate-graph parity by design.
    """

    H: int = 1
    route_compute_weight: float = 0.0

    def _route_scores(self, state, Qt, graph, pe, pc, Ce, Cc, V):
        """Score pass over the route lattice via the selected backend:
        (rc [M,L], l1 [M], b [M]). The phase scope labels it in
        profiler traces (metadata only)."""
        with phase("route_score"):
            row = jnp.concatenate([Ce[None], Cc])         # [N+1]
            VCt = V * row[graph.region]                   # [L]
            Qcr = jnp.take(state.Qc, graph.dest, axis=1)  # [M, L]
            if self.route_compute_weight:
                pcr = jnp.take(pc, graph.dest, axis=1)
                extra = (
                    jnp.asarray(self.route_compute_weight, jnp.float32)
                    * (V * Cc)[graph.dest][None, :] * pcr
                )
            else:
                extra = jnp.zeros_like(Qcr)
            if self.score_backend == "pallas":
                from repro.kernels import ops

                return ops.route_scores(
                    Qt, graph.pt, Qcr, extra, state.Qe, pe, VCt,
                    V * Ce, block_m=self.score_block_m,
                    block_l=self.score_block_n,
                    interpret=self.score_interpret,
                )
            if self.score_backend != "reference":
                raise ValueError(
                    f"unknown score_backend {self.score_backend!r}"
                )
            from repro.kernels import ref

            return ref.route_scores_ref(
                Qt, graph.pt, Qcr, extra, state.Qe, pe, VCt, V * Ce
            )

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        *,
        graph: LinkGraph,
        Qt: Array,
        forecast: Array | None = None,
        fault_view=None,
        deadline_view=None,
    ) -> NetAction:
        del arrivals, key, fault_view, deadline_view
        Ce_eff, Cc_eff = self.effective_intensities(Ce, Cc, forecast)
        pe, pc, Pe, Pc = spec.as_arrays()
        V = jnp.asarray(self.V, jnp.float32)

        # Cloud half: unchanged Algorithm 1 (the c-matrix). Edge half:
        # dispatch each type onto its best route. Both fills run as the
        # parent's one stacked [N+1, M] greedy_fill call.
        c, _, _ = self._scores(state, pe, pc, Ce_eff, Cc_eff, V)
        _, l1, b = self._route_scores(
            state, Qt, graph, pe, pc, Ce_eff, Cc_eff, V
        )
        d_counts, w = self._fill_all(
            b, c, pe, pc, state.Qe, state.Qc, Pe, Pc
        )
        dt = jnp.zeros_like(Qt).at[jnp.arange(spec.M), l1].set(d_counts)
        return NetAction(dt=dt, w=w)


@dataclasses.dataclass(frozen=True)
class StaticRoutePolicy:
    """Transfer-blind adapter: `inner` decides (d, w) as if clouds were
    directly attached; every dispatch to cloud n rides the graph's
    primary route. Qt, bandwidth and link carbon are invisible to it --
    exactly what a scheduler without the WAN layer would do."""

    inner: Callable

    def __call__(
        self,
        state: NetworkState,
        spec: NetworkSpec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        *,
        graph: LinkGraph,
        Qt: Array,
        forecast: Array | None = None,
        fault_view=None,
        deadline_view=None,
    ) -> NetAction:
        del Qt, fault_view
        kwargs = {}
        if forecast is not None:
            kwargs["forecast"] = forecast
        if deadline_view is not None:
            kwargs["deadline_view"] = deadline_view
        act = self.inner(state, spec, Ce, Cc, arrivals, key, **kwargs)
        onehot = jax.nn.one_hot(graph.primary, graph.L, dtype=act.d.dtype)
        return NetAction(dt=act.d @ onehot, w=act.w)
