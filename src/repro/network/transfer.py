"""In-flight transfer dynamics over a LinkGraph.

State is an aggregate pipe model per (task type, route):

  Qt   [M,L] -- tasks in flight (integral counts, float32 like the
                queues in core/queueing.py)
  prog [M,L] -- transfer progress in size-units toward the in-flight
                pool (fractional; < size[m] once completed tasks are
                removed)

Each slot a route drains up to bw[l] size-units, shared across task
types in proportion to their remaining work (processor sharing); a task
lands in its destination's Qc once a full size[m] of progress is booked
against it. Consequences, all covered by tests/test_network.py:

  * a single type-m task on an otherwise idle route l needs
    ceil(size[m] / bw[l]) slots edge->cloud -- transfer latency;
  * sustained throughput of route l is bw[l] size-units/slot -- the
    bandwidth cap (in tasks/slot: bw[l]/size[m]);
  * Qt only ever changes by integer dispatches in and integer
    deliveries out -- no task is lost or duplicated in flight;
  * bw = inf delivers everything the same slot with zero residual
    progress, which is what makes the degenerate direct_graph
    bit-identical to the link-free simulator.

Deliveries are aggregated per destination cloud with a one-hot matmul
(exact for integral counts in float32), so the whole step is dense
linear algebra that scans and vmaps.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.queueing import DTYPE, NetworkSpec, edge_energy

from repro.network.graph import LinkGraph
from repro.telemetry.profile import phase

Array = jax.Array

_TINY = 1e-30  # drain-ratio denominator guard (no NaN even at bw=inf)


class LinkState(NamedTuple):
    Qt: Array    # [M, L] tasks in flight per (type, route)
    prog: Array  # [M, L] size-units transferred toward the pool


class NetAction(NamedTuple):
    """One slot of WAN scheduling: dt routes dispatches, w processes."""

    dt: Array  # [M, L] tasks dispatched onto route l
    w: Array   # [M, N] tasks processed at cloud n


def init_links(M: int, L: int, dtype=DTYPE) -> LinkState:
    z = jnp.zeros((M, L), dtype)
    return LinkState(Qt=z, prog=z)


def step_links(
    ls: LinkState, graph: LinkGraph, dt: Array, bw_scale: Array | None = None
) -> Tuple[LinkState, Array]:
    """Injects dt [M,L] new transfers, drains one slot of bandwidth,
    returns (next state, delivered [M,L] task counts).

    `bw_scale` [L] (repro.faults link flaps) scales each route's
    bandwidth for this slot. The guarded `where` keeps a hard flap
    (scale 0) on an infinite-bandwidth route at exactly 0 instead of
    inf * 0 = NaN; scale 1.0 is a bitwise no-op (inf * 1.0 = inf).

    The phase scope labels the link step in profiler traces
    (repro.telemetry §profiling, metadata only)."""
    with phase("transfer_step"):
        if bw_scale is None:
            bw = graph.bw
        else:
            bw = jnp.where(bw_scale > 0.0, graph.bw * bw_scale, 0.0)
        Qt = ls.Qt + dt
        demand = Qt * graph.size[:, None] - ls.prog      # [M, L] work left
        total = jnp.sum(demand, axis=0)                  # [L]
        ratio = jnp.minimum(1.0, bw / jnp.maximum(total, _TINY))
        prog = ls.prog + demand * ratio
        # Clamp at 0 on both sides of the delivery: cancellation in
        # `prog - delivered*size` can leave prog at -eps, and
        # floor(-eps/size) = -1 would then "deliver" a NEGATIVE task --
        # un-delivering work onto an empty route and driving Qc below
        # zero (the telemetry conservation monitor caught exactly this
        # leak). Where prog >= 0 both clamps are exact no-ops, so the
        # direct-graph parity anchor is untouched.
        delivered = jnp.minimum(
            Qt,
            jnp.maximum(jnp.floor(prog / graph.size[:, None]), 0.0),
        )
        Qt = Qt - delivered
        prog = jnp.maximum(
            prog - delivered * graph.size[:, None], 0.0
        )
        return LinkState(Qt=Qt, prog=prog), delivered


def land_in_clouds(delivered: Array, graph: LinkGraph, N: int) -> Array:
    """Aggregates route deliveries [M,L] into cloud arrivals [M,N]."""
    onehot = jax.nn.one_hot(graph.dest, N, dtype=delivered.dtype)  # [L, N]
    return delivered @ onehot


def transfer_energy(graph: LinkGraph, dt: Array) -> Array:
    """Per-route transfer energy of a dispatch action. Returns [L]."""
    return jnp.sum(dt * graph.pt, axis=0)


def network_emissions(
    spec: NetworkSpec,
    graph: LinkGraph,
    action: NetAction,
    Ce: Array,
    Cc: Array,
) -> Array:
    """End-to-end carbon of one slot: edge dispatch energy at the edge
    intensity, transfer energy priced in each route's carbon region
    (charged when the transfer starts -- same slot the policy scored
    it), compute energy at the destination intensities."""
    pe, pc, _, _ = spec.as_arrays()
    row = jnp.concatenate([Ce[None], Cc])                 # [N+1]
    Ct = row[graph.region]                                # [L]
    return (
        Ce * edge_energy(pe, action.dt)
        + jnp.sum(Ct * transfer_energy(graph, action.dt))
        + jnp.sum(Cc * jnp.sum(action.w * pc, axis=0))
    )
