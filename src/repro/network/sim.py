"""WAN network simulator: the core `simulate` scan with the in-flight
transfer queue threaded through the carry.

`core.simulator.simulate(..., graph=...)` delegates here, so every
existing entry point (simulate_fleet lanes, forecaster threading,
vmapped sweeps) picks up the transfer layer by passing a LinkGraph.
Policies run in this world receive two extra keyword arguments each
slot -- `graph` and the current in-flight queue `Qt [M, L]` -- and
return a `NetAction(dt [M,L], w [M,N])` instead of an Action.

Slot order (mirrors eqs. (7)-(8) with the link hop inserted):
  observe (Ce, Cc), arrivals  ->  act (dt, w)  ->  account emissions
  (edge + per-region transfer + cloud, all at TRUE intensities)  ->
  links inject dt, drain one slot of bandwidth, deliver  ->
  Qe loses dispatches / gains arrivals, Qc loses w / gains deliveries.

With the degenerate `direct_graph` (infinite bandwidth, zero transfer
energy) deliveries equal dispatches in the same slot and the transfer
emission term is exactly +0.0, so the whole trajectory is bit-identical
to the link-free `simulate` -- the parity anchor in tests/test_network.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.queueing import NetworkState, NetworkSpec, init_state
from repro.core.simulator import _record_scan, init_forecaster_carry
from repro.telemetry.stream import split_telemetry
from repro.network.graph import LinkGraph
from repro.network.transfer import (
    NetAction,
    init_links,
    land_in_clouds,
    network_emissions,
    step_links,
    transfer_energy,
)
from repro.telemetry.taps import (
    TelemetryProbe,
    finalize_taps,
    init_taps,
    step_taps,
)

Array = jax.Array


class NetSimResult(NamedTuple):
    emissions: Array        # [T] per-slot end-to-end carbon
    cum_emissions: Array    # [T] cumulative sum
    Qe: Array               # [R, M] edge queues (post-step)
    Qc: Array               # [R, M, N] cloud queues (post-step)
    Qt: Array               # [R, M, L] in-flight transfers (post-step)
    dispatched: Array       # [T] tasks put onto links
    delivered: Array        # [T] tasks landed in cloud queues
    processed: Array        # [T] tasks processed
    energy_edge: Array      # [T] edge dispatch energy
    energy_transfer: Array  # [T] WAN transfer energy
    energy_cloud: Array     # [T, N] cloud compute energy
    telemetry: object = None  # repro.telemetry.Telemetry frame, or None
    deadlines: object = None  # repro.deadlines.DeadlineLedger, or None

    # R depends on the `record` mode exactly as in SimResult: T for
    # "full", 1 for "summary", T//k for stride k.

    @property
    def final_backlog(self) -> Array:
        return (
            self.Qe[-1].sum() + self.Qc[-1].sum() + self.Qt[-1].sum()
        )


def simulate_network(
    policy: Callable,
    spec: NetworkSpec,
    graph: LinkGraph,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
    state0: NetworkState | None = None,
    forecaster: Callable | None = None,
    error_params=None,
    record: str | int = "full",
    faults=None,
    telemetry=None,
    stream_lane=None,
    deadlines=None,
) -> NetSimResult:
    """Runs the network + WAN for T slots under a route-aware policy.

    `forecaster` / `error_params` behave exactly as in
    `core.simulator.simulate`: the forecast carry threads through the
    scan, `error_params = (bias, noise)` overrides the forecaster's
    ForecastErrorModel per call (that is how `simulate_fleet` sweeps
    forecast quality across lanes), and emissions are always accounted
    against the TRUE intensities. `record` controls the Qe/Qc/Qt
    trajectory length exactly as in `simulate` ("full" | "summary" |
    int stride); scalar series always cover all T slots.

    `faults` (a repro.faults.FaultParams built with L=graph.L) routes
    the run through the fault layer: link flaps scale each route's
    bandwidth, cloud outages mask budgets and service, and the result
    is a NetFaultSimResult -- see repro.faults.sim.

    `telemetry` behaves as in `core.simulator.simulate`: taps-on runs
    fill the result's `.telemetry` frame (here `transfer_occupancy`
    tracks the in-flight Qt total and `dispatched_cloud` counts
    LANDINGS per cloud, not link injections); `telemetry=None` runs are
    bit-identical to a build without the telemetry layer.

    `deadlines` behaves as in `core.simulator.simulate`: the deadline
    clock runs on edge waiting (time-to-dispatch onto a link); once a
    task is in flight or queued at a cloud it no longer expires.
    """
    if faults is not None:
        from repro.faults.sim import simulate_network_faulted

        return simulate_network_faulted(
            policy, spec, graph, faults, carbon_source, arrival_source,
            T, key, state0=state0, forecaster=forecaster,
            error_params=error_params, record=record,
            telemetry=telemetry, stream_lane=stream_lane,
            deadlines=deadlines,
        )
    telemetry, stream = split_telemetry(telemetry)
    pe, pc, _, _ = spec.as_arrays()
    if state0 is None:
        state0 = init_state(spec.M, spec.N)
    if deadlines is not None:
        from repro.deadlines.model import (
            DeadlineLedger,
            deadline_view,
            init_deadlines,
            step_deadlines,
        )
    ls0 = init_links(spec.M, graph.L)
    k_carbon, k_arrive, k_policy = jax.random.split(key, 3)

    if forecaster is not None:
        fcarry0 = init_forecaster_carry(
            forecaster, spec.N, k_carbon, carbon_source, error_params
        )

    def body(carry, t):
        state, ls, fcarry, tap, dstate = carry
        Ce, Cc = carbon_source(t, k_carbon)
        a = arrival_source(t, k_arrive)
        k_t = jax.random.fold_in(k_policy, t)
        pkw = {}
        if deadlines is not None:
            pkw["deadline_view"] = deadline_view(deadlines, dstate)
        if forecaster is None:
            act: NetAction = policy(
                state, spec, Ce, Cc, a, k_t, graph=graph, Qt=ls.Qt,
                **pkw,
            )
        else:
            fcarry = forecaster.update(
                fcarry, jnp.concatenate([Ce[None], Cc])
            )
            act = policy(
                state, spec, Ce, Cc, a, k_t, graph=graph, Qt=ls.Qt,
                forecast=forecaster.predict(fcarry, t), **pkw,
            )
        C_t = network_emissions(spec, graph, act, Ce, Cc)
        ls_next, delivered = step_links(ls, graph, act.dt)
        land = land_in_clouds(delivered, graph, spec.N)
        d_sum = jnp.sum(act.dt, axis=1)
        if deadlines is None:
            arr_term = a
            missed = shed = jnp.float32(0.0)
        else:
            dstate, admitted, expired, shed_v = step_deadlines(
                deadlines, dstate, d_sum, a
            )
            arr_term = admitted - expired
            missed = jnp.sum(expired)
            shed = jnp.sum(shed_v)
        nxt = NetworkState(
            Qe=jnp.maximum(state.Qe - d_sum, 0.0) + arr_term,
            Qc=jnp.maximum(state.Qc - act.w, 0.0) + land,
        )
        out = (
            C_t,
            jnp.sum(act.dt),
            jnp.sum(delivered),
            jnp.sum(act.w),
            jnp.sum(act.dt * pe[:, None]),
            jnp.sum(transfer_energy(graph, act.dt)),
            jnp.sum(act.w * pc, axis=0),
        )
        if deadlines is not None:
            out = out + (missed, shed, jnp.sum(admitted))
        if telemetry is None:
            return (nxt, ls_next, fcarry, tap, dstate), out
        probe = TelemetryProbe(
            emissions=C_t,
            arrived=jnp.sum(a),
            dispatched=jnp.sum(land, axis=0),
            processed=jnp.sum(act.w),
            failed=jnp.float32(0.0),
            wasted=jnp.float32(0.0),
            backlog=jnp.sum(nxt.Qe) + jnp.sum(nxt.Qc)
            + jnp.sum(ls_next.Qt),
            stale=jnp.int32(0),
            clouds_down=jnp.float32(0.0),
            retry_depth=jnp.float32(0.0),
            transfer_occupancy=jnp.sum(ls_next.Qt),
            missed=missed,
            shed=shed,
        )
        tap, tseries = step_taps(telemetry, tap, probe)
        return (nxt, ls_next, fcarry, tap, dstate), (out, tseries)

    carry0 = (
        state0, ls0,
        fcarry0 if forecaster is not None else (),
        init_taps() if telemetry is not None else (),
        init_deadlines(spec.M, deadlines.rings.shape[-1])
        if deadlines is not None else (),
    )
    if deadlines is None:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[1].Qt
        )
    else:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[1].Qt, carry[4].Qd
        )
    scalars, states = _record_scan(
        body, state_of,
        carry0, T, record, stream=stream, lane=stream_lane,
    )
    if telemetry is None:
        scal, tel = scalars, None
    else:
        scal, tseries = scalars
        tel = finalize_taps(telemetry, tseries)
    if deadlines is None:
        (C, disp, deliv, proc, ee, et, ec) = scal
        (Qe, Qc, Qt), led = states, None
    else:
        (C, disp, deliv, proc, ee, et, ec, missed, shed, adm) = scal
        Qe, Qc, Qt, Qd = states
        led = DeadlineLedger(missed=missed, shed=shed, admitted=adm,
                             Qd=Qd)
    return NetSimResult(
        emissions=C,
        cum_emissions=jnp.cumsum(C),
        Qe=Qe,
        Qc=Qc,
        Qt=Qt,
        dispatched=disp,
        delivered=deliv,
        processed=proc,
        energy_edge=ee,
        energy_transfer=et,
        energy_cloud=ec,
        telemetry=tel,
        deadlines=led,
    )
