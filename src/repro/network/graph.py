"""WAN link graph for carbon-aware transfer scheduling.

A `LinkGraph` describes the routes a dispatched task can take from the
edge to the clouds. Every route l is characterized by

  dest[l]    -- destination cloud index (several routes may share a
                destination: multi-path / relay alternatives)
  bw[l]      -- bandwidth in size-units per slot (jnp.inf = unconstrained)
  pt[m,l]    -- transfer energy (kWh) to move one type-m task over route l
  region[l]  -- carbon-region index into the [N+1] intensity row
                (0 = edge region, 1..N = cloud regions), pricing the
                route's transfer energy
  size[m]    -- data volume of a type-m task (same units as bw*slot)
  primary[n] -- the designated default route to cloud n (what a
                transfer-blind policy uses)

A physical multi-hop path (edge -> relay cloud -> destination) is
represented as ONE composite route whose pt sums the hop energies, whose
bw is the bottleneck hop, and whose region prices the dominant hop --
that keeps the in-flight state a dense [M, L] array (see transfer.py)
instead of a per-hop token ring. Everything is a flat pytree of arrays,
so graphs stack across fleet lanes and vmap through `simulate_fleet`.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class LinkGraph(NamedTuple):
    dest: Array     # [L] int32 destination cloud per route
    bw: Array       # [L] f32 bandwidth (size-units / slot; inf allowed)
    pt: Array       # [M, L] f32 transfer energy per task
    region: Array   # [L] int32 carbon-region index into the [N+1] row
    size: Array     # [M] f32 data volume per task
    primary: Array  # [N] int32 default route per cloud

    @property
    def L(self) -> int:
        return self.dest.shape[-1]

    @property
    def M(self) -> int:
        return self.size.shape[-1]

    @property
    def N(self) -> int:
        return self.primary.shape[-1]


def make_graph(dest, bw, pt, region, size, primary) -> LinkGraph:
    """Validating constructor from host (numpy/list) data.

    Validation runs on numpy copies of the host inputs -- the jnp
    arrays in the returned ``LinkGraph`` are never forced back to the
    host (no ``bool(jnp.all(...))``), so constructing a graph cannot
    introduce a device sync.
    """
    dest_h = np.asarray(dest, np.int32)
    bw_h = np.asarray(bw, np.float32)
    pt_h = np.asarray(pt, np.float32)
    region_h = np.asarray(region, np.int32)
    size_h = np.asarray(size, np.float32)
    primary_h = np.asarray(primary, np.int32)
    L, M, N = dest_h.shape[-1], size_h.shape[-1], primary_h.shape[-1]
    if bw_h.shape != (L,) or region_h.shape != (L,):
        raise ValueError(f"bw/region must be [{L}]")
    if pt_h.shape != (M, L):
        raise ValueError(f"pt must be [{M}, {L}], got {pt_h.shape}")
    if int(dest_h.max()) >= N or int(dest_h.min()) < 0:
        raise ValueError(f"dest out of range for N={N}")
    if int(region_h.max()) > N or int(region_h.min()) < 0:
        raise ValueError("region indexes the [N+1] intensity row")
    # zero/negative sizes would make floor(prog/size) NaN deep inside
    # the scan; negative bandwidth would silently un-transfer work
    if not np.all(size_h > 0):
        raise ValueError("size must be strictly positive per task type")
    if not np.all(bw_h >= 0):
        raise ValueError("bw must be non-negative (use jnp.inf for "
                         "unconstrained links)")
    return LinkGraph(
        dest=jnp.asarray(dest_h),
        bw=jnp.asarray(bw_h),
        pt=jnp.asarray(pt_h),
        region=jnp.asarray(region_h),
        size=jnp.asarray(size_h),
        primary=jnp.asarray(primary_h),
    )


def direct_graph(M: int, N: int) -> LinkGraph:
    """The degenerate graph: one infinite-bandwidth, zero-transfer-energy
    link per cloud, in cloud order. Tasks dispatched on route n land in
    Qc[:, n] the same slot and add zero transfer carbon, so
    `NetworkAwareDPPPolicy` on this graph is bit-identical to
    `CarbonIntensityPolicy` -- the subsystem's regression anchor
    (tests/test_network.py)."""
    return make_graph(
        dest=np.arange(N),
        bw=np.full((N,), np.inf, np.float32),
        pt=np.zeros((M, N), np.float32),
        region=np.arange(1, N + 1),
        size=np.ones((M,), np.float32),
        primary=np.arange(N),
    )


def star_graph(
    M: int,
    N: int,
    rng: np.random.Generator,
    size: np.ndarray | None = None,
    bw_range=(40.0, 160.0),
    pt_scale: float = 0.6,
) -> LinkGraph:
    """One finite-bandwidth direct link per cloud (hub-and-spoke WAN).
    Transfer energy scales with task size; each link is priced in its
    destination's carbon region."""
    size = (np.ones(M, np.float32) if size is None
            else np.asarray(size, np.float32))
    bw = rng.uniform(*bw_range, N).astype(np.float32)
    pt = (pt_scale * size[:, None]
          * rng.uniform(0.5, 1.5, (1, N))).astype(np.float32)
    return make_graph(
        dest=np.arange(N), bw=bw, pt=pt, region=np.arange(1, N + 1),
        size=size, primary=np.arange(N),
    )


def congested_uplink_graph(
    M: int,
    N: int,
    rng: np.random.Generator,
    size: np.ndarray | None = None,
    clean_bw: float = 25.0,
    dirty_bw: float = 400.0,
    pt_clean: float = 0.4,
    pt_dirty: float = 2.5,
) -> LinkGraph:
    """Two routes per cloud: the default (primary) uplink is wide but
    energy-hungry and priced in a dirty region; the alternate is clean
    and cheap but narrow, so it saturates under load. A transfer-blind
    policy rides the dirty primaries; a route-aware one drains the clean
    alternates first and only spills to the primaries when the in-flight
    backlog Qt prices them out -- the scenario behind the
    `bench_network_routing` acceptance gate. Links l = 2n are the dirty
    primaries, l = 2n+1 the clean alternates."""
    size = (np.ones(M, np.float32) if size is None
            else np.asarray(size, np.float32))
    L = 2 * N
    dest = np.repeat(np.arange(N), 2)
    bw = np.where(np.arange(L) % 2 == 0, dirty_bw, clean_bw).astype(
        np.float32
    ) * rng.uniform(0.9, 1.1, L).astype(np.float32)
    per_link = np.where(np.arange(L) % 2 == 0, pt_dirty, pt_clean)
    pt = (size[:, None] * per_link[None, :]
          * rng.uniform(0.9, 1.1, (1, L))).astype(np.float32)
    # dirty primaries priced in the destination's own region; clean
    # alternates all ride a shared green backbone priced in the LAST
    # cloud's region (row index N -- the congested-uplink scenario
    # generator makes that column the green one).
    region = np.where(np.arange(L) % 2 == 0, dest + 1, N)
    return make_graph(
        dest=dest, bw=bw, pt=pt, region=region, size=size,
        primary=2 * np.arange(N),
    )


def multi_region_wan_graph(
    M: int,
    N: int,
    rng: np.random.Generator,
    size: np.ndarray | None = None,
    relay_overhead: float = 1.8,
) -> LinkGraph:
    """UK-WAN style: every cloud is reachable directly (priced in its own
    region) and via a composite relay route through another region --
    more transfer energy (two hops) but potentially much greener pricing
    when wind fronts decorrelate the regions. Links l = 2n direct,
    l = 2n+1 relayed."""
    size = (np.ones(M, np.float32) if size is None
            else np.asarray(size, np.float32))
    L = 2 * N
    dest = np.repeat(np.arange(N), 2)
    bw = rng.uniform(30.0, 120.0, L).astype(np.float32)
    hop = rng.uniform(0.3, 0.9, L).astype(np.float32)
    per_link = np.where(np.arange(L) % 2 == 0, hop, relay_overhead * hop)
    pt = (size[:, None] * per_link[None, :]).astype(np.float32)
    relay_region = (dest + 1 + rng.integers(1, N, L)) % (N + 1)
    region = np.where(np.arange(L) % 2 == 0, dest + 1, relay_region)
    return make_graph(
        dest=dest, bw=bw, pt=pt, region=region, size=size,
        primary=2 * np.arange(N),
    )


def stack_graphs(graphs: Sequence[LinkGraph]) -> LinkGraph:
    """Stacks graphs (sharing M, N, L) into one pytree with a leading
    fleet axis, for `FleetScenario.graph` / `simulate_fleet`."""
    shapes = {(g.M, g.N, g.L) for g in graphs}
    if len(shapes) != 1:
        raise ValueError(
            f"stacked graphs must share (M, N, L); got {sorted(shapes)}"
        )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *graphs)
