"""Offline forecast evaluation: roll a forecaster over a trace table.

One ``lax.scan`` replays the table as if it were arriving live
(update then predict, exactly like the simulator wiring) and scores
every forecast against the realized future. Pure jnp, so the whole
evaluation jits and vmaps over a stack of tables -- the forecast-
quality regression tests and the example both lean on that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rolling_forecasts(forecaster, table: Array, *, key=None) -> Array:
    """Replays `table` [T, N+1] through `forecaster`; returns the
    forecast tensor [T, H, N+1] (entry [t] is issued after observing
    row t)."""
    table = jnp.asarray(table, jnp.float32)
    N = table.shape[1] - 1
    carry0 = forecaster.init(N, key=key, table=table)

    def body(carry, xs):
        t, row = xs
        carry = forecaster.update(carry, row)
        return carry, forecaster.predict(carry, t)

    T = table.shape[0]
    _, fc = jax.lax.scan(
        body, carry0, (jnp.arange(T), table)
    )
    return fc


def forecast_errors(
    forecaster,
    table: Array,
    *,
    key=None,
    burn_in: int = 0,
) -> dict:
    """MAE / RMSE of `forecaster` on `table`, scored on leads h >= 1
    only (lead 0 is the observed present by contract, hence exact).

    Forecasts whose target slot falls off the end of the table are
    excluded; `burn_in` additionally drops the first slots where
    history-based forecasters are still warming up. Returns scalars
    plus the per-lead MAE profile [H-1].
    """
    table = jnp.asarray(table, jnp.float32)
    T = table.shape[0]
    H = forecaster.H
    fc = rolling_forecasts(forecaster, table, key=key)  # [T, H, N+1]

    h = jnp.arange(1, H)
    # realized value for forecast issued at t, lead h: table[t+h]
    tgt_idx = jnp.arange(T)[:, None] + h[None, :]       # [T, H-1]
    valid = (tgt_idx < T) & (jnp.arange(T)[:, None] >= burn_in)
    truth = table[jnp.clip(tgt_idx, 0, T - 1)]          # [T, H-1, N+1]
    err = fc[:, 1:, :] - truth
    w = jnp.broadcast_to(valid[..., None], err.shape).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    mae = jnp.sum(jnp.abs(err) * w) / denom
    rmse = jnp.sqrt(jnp.sum(err**2 * w) / denom)
    per_lead_denom = jnp.maximum(jnp.sum(w, axis=(0, 2)), 1.0)
    mae_per_lead = jnp.sum(jnp.abs(err) * w, axis=(0, 2)) / per_lead_denom
    return {"mae": mae, "rmse": rmse, "mae_per_lead": mae_per_lead}
