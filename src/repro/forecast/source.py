"""Clairvoyant forecast providers + the forecast-error model.

Real grid operators publish *forecasts*, not the future; the gap
between the two is exactly the axis a lookahead scheduler must be
stress-tested on. This module provides the two clairvoyant endpoints
of that axis and a configurable corruption in between:

  * ForecastErrorModel     -- multiplicative bias + heteroscedastic
    noise whose std grows with lead time and with the intensity level
    (large excursions are the hard-to-predict ones). Lead 0 is always
    exact: the current slot is observed, not forecast.
  * ForecastedCarbonSource -- wraps ANY existing carbon source
    (Random/UKRegional/Table/Constant...) and doubles as a Forecaster:
    it serves the true (Ce, Cc) through ``__call__`` and the
    error-corrupted future through ``predict``. Works because every
    source in core/carbon.py is a pure function of (t, key).
  * ClairvoyantTableForecaster -- forecasts straight off a playback
    table; this is the fleet-path twin (``simulate_fleet`` hands each
    lane its own [Tc, N+1] table via ``init(table=...)``).

Both forecasters honor the shared contract in forecasters.py (init /
update / predict, row 0 = current slot).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ForecastErrorModel:
    """forecast[h] = truth[h] * (1 + bias) + noise * truth[h] * sqrt(h) * eps.

    bias  -- systematic multiplicative error (e.g. +0.1 = 10% over-
             prediction at every lead).
    noise -- heteroscedastic noise fraction: per-lead std is
             ``noise * truth * sqrt(h)``, so error grows with both the
             lead time and the intensity level.
    seed  -- error-realization stream, independent of the world's RNG.

    Lead 0 is returned exactly and the result is clipped at 0 (negative
    intensity forecasts are unphysical). bias=noise=0 is the perfect
    (clairvoyant) forecast.
    """

    bias: float = 0.0
    noise: float = 0.0
    seed: int = 0

    @property
    def exact(self) -> bool:
        return self.bias == 0.0 and self.noise == 0.0

    def apply(
        self,
        truth: Array,
        t: Array,
        key: Array | None = None,
        bias: Array | None = None,
        noise: Array | None = None,
    ) -> Array:
        """truth [H, N+1] -> corrupted forecast [H, N+1]. `key` decorrelates
        realizations across vmapped fleet lanes (each lane folds in its
        own stream); without it every lane would draw identical errors.

        `bias`/`noise` override the dataclass parameters with (possibly
        traced) values -- the per-lane forecast-quality axis of
        `FleetScenario.err_bias/err_noise`. A traced override always
        takes the corrupted path; bias=noise=0.0 there reproduces the
        exact forecast bitwise (x*1.0 + 0.0*... == x)."""
        if bias is None and noise is None and self.exact:
            return truth.astype(jnp.float32)
        b = jnp.asarray(self.bias if bias is None else bias, jnp.float32)
        n = jnp.asarray(self.noise if noise is None else noise, jnp.float32)
        truth = truth.astype(jnp.float32)
        h = jnp.sqrt(jnp.arange(truth.shape[0], dtype=jnp.float32))
        if key is None:
            key = jax.random.PRNGKey(self.seed)
        else:
            key = jax.random.fold_in(key, self.seed)
        eps = jax.random.normal(jax.random.fold_in(key, t), truth.shape,
                                dtype=jnp.float32)
        pred = truth * (1.0 + b) + n * truth * h[:, None] * eps
        pred = pred.at[0].set(truth[0])
        return jnp.maximum(pred, 0.0)


@dataclasses.dataclass(frozen=True)
class ForecastedCarbonSource:
    """A carbon source that also serves its own (possibly corrupted)
    forecast. Use it both ways in one ``simulate`` call:

        src = ForecastedCarbonSource(UKRegionalTraceSource(N=5), H=16,
                                     error=ForecastErrorModel(noise=0.1))
        simulate(policy, spec, src, arrivals, T, key, forecaster=src)

    The simulator passes its carbon key into ``init`` so ``predict``
    evaluates the base source on the *same* realized world it will later
    serve through ``__call__``.
    """

    base: Callable
    H: int = 8
    error: ForecastErrorModel = ForecastErrorModel()

    def __call__(self, t: Array, key: Array) -> Tuple[Array, Array]:
        return self.base(t, key)

    def init(self, N: int, *, key=None, table=None, error=None):
        del N, table
        if key is None:
            key = jax.random.PRNGKey(0)
        bias, noise = (None, None) if error is None else error
        return key, bias, noise

    def update(self, carry, row):
        del row
        return carry

    def predict(self, carry, t):
        key, bias, noise = carry

        def row_at(tt):
            Ce, Cc = self.base(tt, key)
            return jnp.concatenate([Ce[None], Cc]).astype(jnp.float32)

        truth = jax.vmap(row_at)(t + jnp.arange(self.H))
        return self.error.apply(truth, t, key=key, bias=bias, noise=noise)


@dataclasses.dataclass(frozen=True)
class ClairvoyantTableForecaster:
    """Reads the future straight off a playback table (rows repeat
    modulo the table length, matching TableCarbonSource / the fleet
    engine). The table arrives through ``init(table=...)``: in
    ``simulate_fleet`` each vmap lane hands in its own [Tc, N+1] slab,
    so one forecaster instance serves the whole fleet."""

    H: int = 8
    error: ForecastErrorModel = ForecastErrorModel()

    def init(self, N: int, *, key=None, table=None, error=None):
        if table is None:
            raise ValueError(
                "ClairvoyantTableForecaster needs a playback table: pass a "
                "table-backed carbon source (TableCarbonSource / fleet "
                "lane) or use ForecastedCarbonSource for functional sources"
            )
        if key is None:
            key = jax.random.PRNGKey(0)
        bias, noise = (None, None) if error is None else error
        return jnp.asarray(table, jnp.float32), key, bias, noise

    def update(self, carry, row):
        del row
        return carry

    def predict(self, carry, t):
        table, key, bias, noise = carry
        idx = (t + jnp.arange(self.H)) % table.shape[0]
        return self.error.apply(
            table[idx], t, key=key, bias=bias, noise=noise
        )
