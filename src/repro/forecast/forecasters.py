"""Pure-JAX carbon-intensity forecasters (beyond-paper subsystem).

A *forecaster* turns the stream of observed intensity rows into an
``[H, N+1]`` forecast each slot (column 0 = edge region, columns
1..N = clouds, matching the playback-table layout in
``core/carbon.py``). The contract shared by every implementation:

    H : int                                  -- horizon (slots)
    init(N, *, key=None, table=None, error=None) -> carry  (pytree)
        `error` is an optional (bias, noise) override pair for
        clairvoyant forecasters' ForecastErrorModel (the per-lane
        forecast-quality axis of FleetScenario); statistical
        forecasters ignore it -- their error IS the forecast error.
    update(carry, row [N+1]) -> carry        -- observe slot t's row
    predict(carry, t) -> [H, N+1] float32    -- row 0 = slot t (the
        last *observed* row), rows h>=1 = predictions for t+h

``update`` runs before ``predict`` each slot, so row 0 of every
forecast is the intensity the policy already observes -- that is what
makes ``LookaheadDPPPolicy(H=1)`` collapse exactly onto the myopic
policy. All state lives in the carry pytree and every method is pure
jnp, so forecasters thread through ``lax.scan`` and vmap across fleet
instances unchanged.

Implementations (increasing sophistication):

  * PersistenceForecaster   -- tomorrow == today. The baseline every
    forecasting paper must beat.
  * SeasonalNaiveForecaster -- value one period ago (period in slots;
    default 48 = one day of 30-min slots, matching ``diurnal_table``).
  * EWMAForecaster          -- exponentially-weighted level, flat ahead.
  * RidgeARForecaster       -- per-region linear AR(p) with intercept,
    ridge-regularized least squares refit on a sliding window every
    slot, rolled forward H steps with ``lax.scan``.

Clairvoyant (table/source-backed) forecasters live in
``forecast/source.py``; accuracy metrics in ``forecast/metrics.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

Array = jax.Array


@runtime_checkable
class Forecaster(Protocol):
    """Structural type for everything `simulate(..., forecaster=)` accepts."""

    H: int

    def init(self, N: int, *, key=None, table=None, error=None) -> Any:
        ...

    def update(self, carry: Any, row: Array) -> Any:
        ...

    def predict(self, carry: Any, t: Array) -> Array:
        ...


def _tile_last(row: Array, H: int) -> Array:
    """[N+1] -> [H, N+1] persistence forecast."""
    return jnp.broadcast_to(row, (H,) + row.shape).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class PersistenceForecaster:
    """forecast(t+h) = observation(t) for every h."""

    H: int = 8

    def init(self, N: int, *, key=None, table=None, error=None):
        del key, table, error
        return jnp.zeros((N + 1,), jnp.float32)

    def update(self, carry, row):
        del carry
        return row.astype(jnp.float32)

    def predict(self, carry, t):
        del t
        return _tile_last(carry, self.H)


@dataclasses.dataclass(frozen=True)
class SeasonalNaiveForecaster:
    """forecast(t+h) = observation(t+h-period): the previous day's value
    at the same slot-of-day. Falls back to persistence until a full
    period has been observed. `period` defaults to the 48 half-hour
    slots/day used by ``diurnal_table`` / the ESO traces."""

    H: int = 8
    period: int = 48

    def init(self, N: int, *, key=None, table=None, error=None):
        del key, table, error
        buf = jnp.zeros((self.period, N + 1), jnp.float32)
        return buf, jnp.int32(0)

    def update(self, carry, row):
        buf, count = carry
        buf = jnp.roll(buf, -1, axis=0).at[-1].set(row.astype(jnp.float32))
        return buf, count + 1

    def predict(self, carry, t):
        del t
        buf, count = carry
        # After k>=period updates buf[-1] = obs(t), buf[0] = obs(t-period+1),
        # so obs(t+h-period) sits at index h-1 (h in 1..period).
        h = jnp.arange(1, self.H)
        seasonal = buf[(h - 1) % self.period]
        fc = jnp.concatenate([buf[-1:], seasonal], axis=0)
        ready = count >= self.period
        return jnp.where(ready, fc, _tile_last(buf[-1], self.H))


@dataclasses.dataclass(frozen=True)
class EWMAForecaster:
    """Exponentially-weighted moving-average level, forecast flat ahead.
    Row 0 stays the raw last observation (the policy's known present)."""

    H: int = 8
    alpha: float = 0.3

    def init(self, N: int, *, key=None, table=None, error=None):
        del key, table, error
        z = jnp.zeros((N + 1,), jnp.float32)
        return z, z, jnp.int32(0)  # (level, last_row, count)

    def update(self, carry, row):
        level, _, count = carry
        row = row.astype(jnp.float32)
        level = jnp.where(
            count == 0, row, self.alpha * row + (1.0 - self.alpha) * level
        )
        return level, row, count + 1

    def predict(self, carry, t):
        del t
        level, last, _ = carry
        ahead = jnp.broadcast_to(level, (self.H - 1,) + level.shape)
        return jnp.concatenate([last[None], ahead], axis=0)


@dataclasses.dataclass(frozen=True)
class RidgeARForecaster:
    """Per-region AR(p) with intercept, refit every slot by ridge least
    squares on the last `window` observations, rolled forward H-1 steps.

    The fit is the closed-form normal-equation solve
    theta = (X'X + ridge*I)^-1 X'y per region (vmapped over regions);
    the multi-step rollout is a ``lax.scan`` feeding each prediction
    back into the lag window. Falls back to persistence until the
    window is entirely real observations (`window` updates) -- fitting
    earlier would regress on the fabricated zeros the buffer starts
    with.
    """

    H: int = 8
    lags: int = 8
    window: int = 64
    ridge: float = 1.0

    def init(self, N: int, *, key=None, table=None, error=None):
        del key, table, error
        assert self.window >= 2 * self.lags, "window too short to fit AR"
        buf = jnp.zeros((self.window, N + 1), jnp.float32)
        return buf, jnp.int32(0)

    def update(self, carry, row):
        buf, count = carry
        buf = jnp.roll(buf, -1, axis=0).at[-1].set(row.astype(jnp.float32))
        return buf, count + 1

    def _fit_column(self, col: Array) -> Array:
        """col [window] -> theta [lags+1] (AR coefficients + intercept)."""
        p, L = self.lags, self.window
        idx = jnp.arange(L - p)[:, None] + jnp.arange(p)[None, :]
        X = col[idx]                                   # [L-p, p]
        X = jnp.concatenate([X, jnp.ones((L - p, 1), col.dtype)], axis=1)
        y = col[p:]
        XtX = X.T @ X + self.ridge * jnp.eye(p + 1, dtype=col.dtype)
        return jnp.linalg.solve(XtX, X.T @ y)

    def predict(self, carry, t):
        del t
        buf, count = carry
        theta = jax.vmap(self._fit_column, in_axes=1, out_axes=1)(buf)
        # theta [lags+1, N+1]; rollout feeds predictions back in.
        lagwin = buf[-self.lags:]                      # [p, N+1]

        def roll(win, _):
            nxt = jnp.sum(win * theta[: self.lags], axis=0) + theta[-1]
            nxt = jnp.maximum(nxt, 0.0)  # intensities are nonnegative
            win = jnp.roll(win, -1, axis=0).at[-1].set(nxt)
            return win, nxt

        _, ahead = jax.lax.scan(roll, lagwin, None, length=self.H - 1)
        fc = jnp.concatenate([buf[-1:], ahead], axis=0)
        # Not ready until the whole window holds real observations: a
        # partially-filled buffer would fit theta on the fabricated
        # zeros from init (and their zero->real jump).
        ready = count >= self.window
        return jnp.where(ready, fc, _tile_last(buf[-1], self.H))
