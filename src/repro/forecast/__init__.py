"""Carbon-intensity forecasting + forecast-quality stress axis.

Forecasters produce an [H, N+1] intensity forecast each slot (row 0 =
the observed present); ``LookaheadDPPPolicy`` consumes them through
``simulate(..., forecaster=...)``. See forecasters.py for the shared
contract and DESIGN.md §Receding-horizon lookahead for the policy math.
"""
from repro.forecast.forecasters import (
    EWMAForecaster,
    Forecaster,
    PersistenceForecaster,
    RidgeARForecaster,
    SeasonalNaiveForecaster,
)
from repro.forecast.metrics import forecast_errors, rolling_forecasts
from repro.forecast.source import (
    ClairvoyantTableForecaster,
    ForecastErrorModel,
    ForecastedCarbonSource,
)

__all__ = [
    "Forecaster",
    "PersistenceForecaster",
    "SeasonalNaiveForecaster",
    "EWMAForecaster",
    "RidgeARForecaster",
    "ForecastErrorModel",
    "ForecastedCarbonSource",
    "ClairvoyantTableForecaster",
    "forecast_errors",
    "rolling_forecasts",
]
