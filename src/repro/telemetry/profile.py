"""Phase annotation for xprof / Perfetto traces.

`phase("policy_score")` is a thin wrapper around `jax.named_scope`: it
attaches a `repro.<name>/` prefix to every HLO op traced under it, so a
profiler timeline (``jax.profiler.trace`` + xprof, or a Perfetto dump)
shows the simulator's slot anatomy -- policy-score, greedy-fill,
transfer-step, fault-step -- instead of a wall of fused ops. Scopes are
metadata only: they never change the computation, so every bit-parity
anchor in the test suite holds with them in place.

The canonical phase names live in `PHASES` so dashboards and trace
post-processors can rely on them.
"""
from __future__ import annotations

import contextlib

import jax

# The slot anatomy, in execution order. Keep in sync with the scopes
# placed in core/policies.py, network/transfer.py and faults/model.py.
PHASES = (
    "policy_score",   # DPP score tables (reference or pallas backend)
    "route_score",    # WAN (type, route, cloud) score tables
    "greedy_fill",    # chunked top_k budget fill
    "transfer_step",  # link injection / drain / delivery
    "fault_step",     # fault chain transitions + observation masking
    "fault_retry",    # failure draws + retry-pool backoff
)


def phase(name: str):
    """Context manager labelling ops traced inside it as `repro.<name>`."""
    return jax.named_scope(f"repro.{name}")


@contextlib.contextmanager
def trace_to(logdir: str):
    """Host-side convenience: records a `jax.profiler` trace (viewable
    in xprof/TensorBoard or as a Perfetto dump) for the enclosed block.
    Purely host-side -- never call under jit."""
    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
