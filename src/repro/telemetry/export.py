"""Host-side exporters for recorded Telemetry frames.

Everything here runs host-side on concrete numpy values. The batch
exporters run AFTER the compiled call returns -- by design there is no
io_callback in the default traced program, so the audit's
effect-freedom gate stays meaningful and the exporters can never
perturb a run (DESIGN.md §Observability). `follow_run` is the live
consumer for the opt-in streaming path (telemetry.stream): it
subscribes to a StreamChannel and re-renders the same wire formats
incrementally while the scan is still executing.

Three wire formats, each with a parse-checking validator the tests and
the CI telemetry-smoke job run against real output:

* Prometheus text exposition (`to_prometheus`): run-end counters and
  gauges, alert state labelled by monitor, per-cloud dispatch labelled
  by cloud.
* JSON-lines events (`to_jsonl`): one `slot` event per slot, one
  `alert` event per tripped monitor, one terminal `summary` event.
* Chrome trace (`to_chrome_trace`): counter tracks for every scalar
  series plus duration events for alert windows -- load in Perfetto /
  chrome://tracing next to a `profile.trace_to` dump.

Fleet frames ([F, ...] leaves) reduce through `manifest`; the
per-slot exporters take a single lane (`taps.lane(frame, i)`).
"""
from __future__ import annotations

import json
import re
from pathlib import Path

import numpy as np

from repro.telemetry.monitors import MONITORS
from repro.telemetry.taps import METRICS, Telemetry

# Scalar per-slot series exported as event fields / counter tracks.
_SCALAR_SERIES = tuple(
    m.field for m in METRICS
    if m.kind == "series" and m.field != "dispatched_cloud"
)
_COUNTERS = tuple(m for m in METRICS if m.kind == "counter")
_GAUGES = tuple(m for m in METRICS if m.kind == "gauge")


def _require_lane(frame: Telemetry) -> None:
    if np.asarray(frame.peak_backlog).ndim != 0:
        raise ValueError(
            "fleet frame: per-slot exporters take one lane -- select it "
            "with repro.telemetry.lane(frame, i), or reduce the whole "
            "fleet with repro.telemetry.manifest(frame)"
        )


def _prom_name(spec) -> str:
    # Prometheus counters end in _total by convention.
    if spec.kind == "counter":
        return "repro_" + spec.field.replace("total_", "") + "_total"
    return "repro_" + spec.field


def to_prometheus(frame: Telemetry) -> str:
    """Prometheus text exposition of the run-end state: counters,
    gauges, the final value of every scalar series, per-cloud dispatch
    totals, and the alert records labelled by monitor."""
    _require_lane(frame)
    lines = []

    def emit(name, kind, help_, samples):
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {kind}")
        for labels, value in samples:
            lines.append(f"{name}{labels} {value:.10g}")

    for spec in _COUNTERS + _GAUGES:
        kind = "counter" if spec.kind == "counter" else "gauge"
        v = float(np.asarray(getattr(frame, spec.field)))
        emit(_prom_name(spec), kind, f"{spec.help} ({spec.unit})",
             [("", v)])
    for field in _SCALAR_SERIES:
        spec = next(m for m in METRICS if m.field == field)
        v = float(np.asarray(getattr(frame, field))[-1])
        emit(_prom_name(spec) + "_last", "gauge",
             f"final-slot {spec.help} ({spec.unit})", [("", v)])
    disp = np.asarray(frame.dispatched_cloud).sum(axis=0)
    emit("repro_dispatched_cloud_total", "counter",
         "tasks landed per cloud queue (tasks)",
         [(f'{{cloud="{n}"}}', float(disp[n]))
          for n in range(disp.shape[0])])
    for name, help_ in (
        ("repro_alert_tripped", "monitor fired at least once (bool)"),
        ("repro_alert_first_slot", "first firing slot (-1 = never)"),
        ("repro_alert_count", "number of firing slots"),
    ):
        arr = np.asarray(getattr(frame, name.replace("repro_", "")))
        emit(name, "gauge", help_,
             [(f'{{monitor="{mon}"}}', float(arr[k]))
              for k, mon in enumerate(MONITORS)])
    return "\n".join(lines) + "\n"


def to_jsonl(frame: Telemetry) -> str:
    """JSON-lines event stream: `slot` events (one per slot, every
    scalar series plus the per-cloud dispatch vector), `alert` events
    for tripped monitors, and a terminal `summary` event."""
    _require_lane(frame)
    series = {f: np.asarray(getattr(frame, f)) for f in _SCALAR_SERIES}
    disp = np.asarray(frame.dispatched_cloud)
    active = np.asarray(frame.alert_active)
    T = disp.shape[0]
    out = []
    for t in range(T):
        ev = {"event": "slot", "t": t}
        for f, arr in series.items():
            ev[f] = float(arr[t])
        ev["dispatched_cloud"] = [float(x) for x in disp[t]]
        ev["alerts_active"] = [
            mon for k, mon in enumerate(MONITORS) if active[t, k]
        ]
        out.append(json.dumps(ev))
    tripped = np.asarray(frame.alert_tripped)
    first = np.asarray(frame.alert_first_slot)
    count = np.asarray(frame.alert_count)
    for k, mon in enumerate(MONITORS):
        if tripped[k]:
            out.append(json.dumps({
                "event": "alert", "monitor": mon,
                "first_slot": int(first[k]),
                "slots_active": int(count[k]),
            }))
    summary = {"event": "summary"}
    for spec in _COUNTERS + _GAUGES:
        summary[spec.field] = float(np.asarray(getattr(frame, spec.field)))
    out.append(json.dumps(summary))
    return "\n".join(out) + "\n"


def to_chrome_trace(frame: Telemetry, slot_us: float = 1000.0) -> str:
    """Chrome trace-event JSON: one counter track per scalar series
    (ph="C") and one duration event per contiguous alert window
    (ph="X"), slot t at timestamp t*slot_us. Loads in Perfetto /
    chrome://tracing."""
    _require_lane(frame)
    events = [
        {"name": "process_name", "ph": "M", "pid": 0,
         "args": {"name": "repro.telemetry"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "series"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 1,
         "args": {"name": "alerts"}},
    ]
    for field in _SCALAR_SERIES:
        arr = np.asarray(getattr(frame, field))
        for t in range(arr.shape[0]):
            events.append({
                "name": field, "ph": "C", "pid": 0, "tid": 0,
                "ts": t * slot_us, "args": {field: float(arr[t])},
            })
    active = np.asarray(frame.alert_active)
    for k, mon in enumerate(MONITORS):
        col = active[:, k]
        t = 0
        while t < col.shape[0]:
            if col[t]:
                start = t
                while t < col.shape[0] and col[t]:
                    t += 1
                events.append({
                    "name": f"alert:{mon}", "ph": "X", "cat": "alert",
                    "pid": 0, "tid": 1, "ts": start * slot_us,
                    "dur": (t - start) * slot_us,
                })
            else:
                t += 1
    return json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms"}
    )


def manifest(frame: Telemetry) -> dict:
    """Reduces a Telemetry frame (single-lane or fleet) to the plain
    JSON manifest the bench rows carry: peak backlog (max over lanes),
    emission/waste/failure totals (summed over lanes), and per-monitor
    alert records (lanes tripped, firing-slot total, earliest
    first-trip slot across lanes)."""
    K = len(MONITORS)
    out = {
        "peak_backlog": float(np.max(np.asarray(frame.peak_backlog))),
        "total_emissions": float(
            np.sum(np.asarray(frame.total_emissions))
        ),
        "total_wasted": float(np.sum(np.asarray(frame.total_wasted))),
        "total_failed": float(np.sum(np.asarray(frame.total_failed))),
        "alerts": {},
    }
    tripped = np.asarray(frame.alert_tripped).reshape(-1, K)
    first = np.asarray(frame.alert_first_slot).reshape(-1, K)
    count = np.asarray(frame.alert_count).reshape(-1, K)
    for k, mon in enumerate(MONITORS):
        fs = first[:, k][first[:, k] >= 0]
        out["alerts"][mon] = {
            "tripped": int(tripped[:, k].sum()),
            "slots_active": int(count[:, k].sum()),
            "first_slot": int(fs.min()) if fs.size else -1,
        }
    return out


def oracle_gap_series(result, carbon_table, horizon=None):
    """Per-slot clairvoyant re-pricing of the run's energy profile:
    returns `(oracle_rate [T], gap [T])` float32 where `gap` is the
    realized per-slot emissions minus the windowed-min repriced cost of
    the same energy (the per-slot refinement of
    `core.extensions.oracle_emissions_horizon`: `oracle_rate.sum()`
    equals that bound on the tiled table). For WAN results the transfer
    term stays in `gap` un-repriced -- the oracle covers edge + cloud
    energy only. Host-side numpy on a finished result, like the oracle
    bounds themselves.
    """
    em = np.asarray(result.emissions, np.float64)
    T = em.shape[0]
    ci = np.asarray(carbon_table, np.float64)
    ci = ci[np.arange(T) % ci.shape[0]]
    H = T if horizon is None else int(min(max(horizon, 1), T))
    wmin = ci.copy()
    for h in range(1, H):
        np.minimum(wmin, np.roll(ci, -h, axis=0), out=wmin)
    ee = np.asarray(result.energy_edge, np.float64).reshape(T)
    ec = np.asarray(result.energy_cloud, np.float64).reshape(T, -1)
    oracle = ee * wmin[:, 0] + (ec * wmin[:, 1:]).sum(axis=1)
    return oracle.astype(np.float32), (em - oracle).astype(np.float32)


class FollowedRun:
    """Live consumer for a streaming run (see telemetry.stream).

    Subscribes to the named StreamChannel: every flushed TapSeries
    slice appends one JSONL `slot` event per slot (the same fields
    `to_jsonl` writes, plus the fleet `lane`) and rewrites a running
    Prometheus snapshot. `close()` detaches, appends the terminal
    `summary` event and returns the paths, so the live file passes the
    same `validate_jsonl` gate as batch output. With `outdir=None`
    nothing is written -- the object still accumulates totals and
    serves `series(lane)` (the bitwise reassembly of the batch
    TapSeries, delegated to the channel buffer).

    Flush callbacks fire from XLA runtime threads and lanes interleave:
    all mutation happens under one lock, and events are keyed by their
    payload (lane, t) rather than arrival order.
    """

    def __init__(self, channel_name: str = "default", outdir=None,
                 stem: str = "live"):
        import threading

        from repro.telemetry.stream import channel

        self._channel = channel(channel_name)
        self._lock = threading.Lock()
        self._lanes: set = set()
        self._slots = 0
        self._flushes = 0
        self._totals = {
            "total_emissions": 0.0, "total_arrived": 0.0,
            "total_processed": 0.0, "total_failed": 0.0,
            "total_wasted": 0.0,
        }
        self._last_backlog: dict = {}
        self.paths: dict = {}
        if outdir is not None:
            outdir = Path(outdir)
            outdir.mkdir(parents=True, exist_ok=True)
            self.paths = {
                "jsonl": outdir / f"{stem}.jsonl",
                "prometheus": outdir / f"{stem}.prom",
            }
            self.paths["jsonl"].write_text("")
        self._closed = False
        self._channel.subscribe(self._on_flush)

    # -- consumer side -------------------------------------------------

    def _on_flush(self, lane: int, t0: int, slice_) -> None:
        T = np.asarray(slice_.arrived).shape[0]
        active = np.asarray(slice_.alert_active)
        events = []
        for i in range(T):
            ev = {"event": "slot", "lane": int(lane), "t": int(t0 + i)}
            for f in _SCALAR_SERIES:
                ev[f] = float(np.asarray(getattr(slice_, f))[i])
            ev["dispatched_cloud"] = [
                float(x) for x in np.asarray(slice_.dispatched_cloud)[i]
            ]
            ev["alerts_active"] = [
                mon for k, mon in enumerate(MONITORS) if active[i, k]
            ]
            events.append(json.dumps(ev))
        with self._lock:
            self._flushes += 1
            self._slots += T
            self._lanes.add(int(lane))
            self._totals["total_emissions"] += float(
                np.asarray(slice_.emission_rate).sum()
            )
            self._totals["total_arrived"] += float(
                np.asarray(slice_.arrived).sum()
            )
            self._totals["total_processed"] += float(
                np.asarray(slice_.processed).sum()
            )
            self._totals["total_failed"] += float(
                np.asarray(slice_.failed).sum()
            )
            self._totals["total_wasted"] += float(
                np.asarray(slice_.wasted).sum()
            )
            self._last_backlog[int(lane)] = float(
                np.asarray(slice_.backlog)[-1]
            )
            if self.paths:
                with self.paths["jsonl"].open("a") as fh:
                    fh.write("\n".join(events) + "\n")
                self.paths["prometheus"].write_text(
                    self._prometheus_locked()
                )

    def _prometheus_locked(self) -> str:
        lines = []

        def emit(name, kind, help_, samples):
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in samples:
                lines.append(f"{name}{labels} {value:.10g}")

        emit("repro_stream_flushes", "counter",
             "TapSeries slices flushed so far", [("", self._flushes)])
        emit("repro_stream_slots", "counter",
             "lane-slots streamed so far", [("", self._slots)])
        emit("repro_stream_lanes", "gauge",
             "fleet lanes seen so far", [("", len(self._lanes))])
        for key, val in self._totals.items():
            emit(f"repro_stream_{key.replace('total_', '')}_total",
                 "counter", f"running {key} over streamed slots",
                 [("", val)])
        emit("repro_stream_backlog_last", "gauge",
             "backlog at each lane's newest streamed slot",
             [(f'{{lane="{ln}"}}', v)
              for ln, v in sorted(self._last_backlog.items())])
        return "\n".join(lines) + "\n"

    # -- reader side ---------------------------------------------------

    def to_prometheus(self) -> str:
        with self._lock:
            return self._prometheus_locked()

    @property
    def slots(self) -> int:
        with self._lock:
            return self._slots

    def lanes(self):
        with self._lock:
            return sorted(self._lanes)

    def totals(self) -> dict:
        with self._lock:
            return dict(self._totals)

    def series(self, lane: int = 0):
        """The reassembled [T, ...] TapSeries for one lane (bitwise
        equal to the batch frame's series; see StreamChannel.series)."""
        return self._channel.series(lane)

    def close(self) -> dict:
        """Detaches from the channel, writes the terminal `summary`
        event + final Prometheus snapshot, and returns the paths."""
        if self._closed:
            return self.paths
        self._channel.unsubscribe(self._on_flush)
        self._closed = True
        with self._lock:
            if self.paths:
                summary = {
                    "event": "summary", "lanes": len(self._lanes),
                    "slots": self._slots, "flushes": self._flushes,
                    **self._totals,
                }
                with self.paths["jsonl"].open("a") as fh:
                    fh.write(json.dumps(summary) + "\n")
                self.paths["prometheus"].write_text(
                    self._prometheus_locked()
                )
        return self.paths

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def follow_run(channel: str = "default", outdir=None,
               stem: str = "live") -> FollowedRun:
    """Attaches a live consumer to a streaming channel: returns a
    FollowedRun already subscribed (use as a context manager around the
    compiled call; see README §Watching a run, live mode)."""
    return FollowedRun(channel, outdir=outdir, stem=stem)


def write_run(frame: Telemetry, outdir, stem: str = "run") -> dict:
    """Writes all three wire formats for one lane; returns the paths."""
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    paths = {
        "prometheus": outdir / f"{stem}.prom",
        "jsonl": outdir / f"{stem}.jsonl",
        "chrome_trace": outdir / f"{stem}.trace.json",
    }
    paths["prometheus"].write_text(to_prometheus(frame))
    paths["jsonl"].write_text(to_jsonl(frame))
    paths["chrome_trace"].write_text(to_chrome_trace(frame))
    return paths


_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})?\s+[-+]?"
    r"([0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?|[Nn]a[Nn]|[Ii]nf)$"
)


def validate_prometheus(text: str) -> int:
    """Parse-checks Prometheus text exposition; returns sample count.
    Histogram samples use the conventional `<base>_bucket` /
    `<base>_sum` / `<base>_count` suffixes under one `TYPE <base>
    histogram` declaration."""
    samples = 0
    typed = set()
    histograms = set()
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) < 4 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"bad comment line {i + 1}: {line!r}")
            if parts[1] == "TYPE":
                typed.add(parts[2])
                if parts[3] == "histogram":
                    histograms.add(parts[2])
            continue
        if not _PROM_SAMPLE.match(line):
            raise ValueError(f"bad sample line {i + 1}: {line!r}")
        name = line.split("{")[0].split()[0]
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in typed and base not in histograms:
            raise ValueError(f"sample before TYPE for {name!r}")
        samples += 1
    if samples == 0:
        raise ValueError("no samples")
    return samples


def validate_jsonl(text: str) -> int:
    """Parse-checks a JSON-lines event stream; returns event count."""
    events = 0
    kinds = set()
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        ev = json.loads(line)
        if "event" not in ev:
            raise ValueError(f"line {i + 1} missing 'event' field")
        kinds.add(ev["event"])
        events += 1
    if "slot" not in kinds or "summary" not in kinds:
        raise ValueError(f"missing slot/summary events (saw {kinds})")
    return events


def validate_chrome_trace(text: str) -> int:
    """Parse-checks Chrome trace-event JSON; returns event count."""
    doc = json.loads(text)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents missing or empty")
    for i, ev in enumerate(events):
        if "ph" not in ev or "name" not in ev:
            raise ValueError(f"event {i} missing ph/name: {ev!r}")
        if ev["ph"] in ("C", "X") and "ts" not in ev:
            raise ValueError(f"event {i} missing ts: {ev!r}")
    return len(events)


def validate_dir(outdir, formats=("prom", "jsonl", "trace")) -> dict:
    """Validates every telemetry file under `outdir` (the CI
    telemetry-smoke gate); requires at least one file of each format
    in `formats` (default: all three). Live-mode directories carry no
    Chrome trace -- the serving-smoke gate passes
    `formats=("prom", "jsonl")`. Returns {path: event/sample count}."""
    outdir = Path(outdir)
    all_checks = {
        "prom": ("*.prom", validate_prometheus),
        "jsonl": ("*.jsonl", validate_jsonl),
        "trace": ("*.trace.json", validate_chrome_trace),
    }
    unknown = set(formats) - set(all_checks)
    if unknown:
        raise ValueError(f"unknown formats: {sorted(unknown)}")
    checks = {all_checks[f][0]: all_checks[f][1] for f in formats}
    out = {}
    for pattern, fn in checks.items():
        paths = sorted(outdir.glob(pattern))
        if not paths:
            raise ValueError(f"no {pattern} files under {outdir}")
        for p in paths:
            out[str(p)] = fn(p.read_text())
    return out
