"""Host-side exporters for recorded Telemetry frames.

Everything here runs AFTER the compiled call returns (plain
numpy/json on concrete arrays) -- by design there is no io_callback in
the traced program, so the audit's effect-freedom gate stays meaningful
and the exporters can never perturb a run (DESIGN.md §Observability).

Three wire formats, each with a parse-checking validator the tests and
the CI telemetry-smoke job run against real output:

* Prometheus text exposition (`to_prometheus`): run-end counters and
  gauges, alert state labelled by monitor, per-cloud dispatch labelled
  by cloud.
* JSON-lines events (`to_jsonl`): one `slot` event per slot, one
  `alert` event per tripped monitor, one terminal `summary` event.
* Chrome trace (`to_chrome_trace`): counter tracks for every scalar
  series plus duration events for alert windows -- load in Perfetto /
  chrome://tracing next to a `profile.trace_to` dump.

Fleet frames ([F, ...] leaves) reduce through `manifest`; the
per-slot exporters take a single lane (`taps.lane(frame, i)`).
"""
from __future__ import annotations

import json
import re
from pathlib import Path

import numpy as np

from repro.telemetry.monitors import MONITORS
from repro.telemetry.taps import METRICS, Telemetry

# Scalar per-slot series exported as event fields / counter tracks.
_SCALAR_SERIES = tuple(
    m.field for m in METRICS
    if m.kind == "series" and m.field != "dispatched_cloud"
)
_COUNTERS = tuple(m for m in METRICS if m.kind == "counter")
_GAUGES = tuple(m for m in METRICS if m.kind == "gauge")


def _require_lane(frame: Telemetry) -> None:
    if np.asarray(frame.peak_backlog).ndim != 0:
        raise ValueError(
            "fleet frame: per-slot exporters take one lane -- select it "
            "with repro.telemetry.lane(frame, i), or reduce the whole "
            "fleet with repro.telemetry.manifest(frame)"
        )


def _prom_name(spec) -> str:
    # Prometheus counters end in _total by convention.
    if spec.kind == "counter":
        return "repro_" + spec.field.replace("total_", "") + "_total"
    return "repro_" + spec.field


def to_prometheus(frame: Telemetry) -> str:
    """Prometheus text exposition of the run-end state: counters,
    gauges, the final value of every scalar series, per-cloud dispatch
    totals, and the alert records labelled by monitor."""
    _require_lane(frame)
    lines = []

    def emit(name, kind, help_, samples):
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {kind}")
        for labels, value in samples:
            lines.append(f"{name}{labels} {value:.10g}")

    for spec in _COUNTERS + _GAUGES:
        kind = "counter" if spec.kind == "counter" else "gauge"
        v = float(np.asarray(getattr(frame, spec.field)))
        emit(_prom_name(spec), kind, f"{spec.help} ({spec.unit})",
             [("", v)])
    for field in _SCALAR_SERIES:
        spec = next(m for m in METRICS if m.field == field)
        v = float(np.asarray(getattr(frame, field))[-1])
        emit(_prom_name(spec) + "_last", "gauge",
             f"final-slot {spec.help} ({spec.unit})", [("", v)])
    disp = np.asarray(frame.dispatched_cloud).sum(axis=0)
    emit("repro_dispatched_cloud_total", "counter",
         "tasks landed per cloud queue (tasks)",
         [(f'{{cloud="{n}"}}', float(disp[n]))
          for n in range(disp.shape[0])])
    for name, help_ in (
        ("repro_alert_tripped", "monitor fired at least once (bool)"),
        ("repro_alert_first_slot", "first firing slot (-1 = never)"),
        ("repro_alert_count", "number of firing slots"),
    ):
        arr = np.asarray(getattr(frame, name.replace("repro_", "")))
        emit(name, "gauge", help_,
             [(f'{{monitor="{mon}"}}', float(arr[k]))
              for k, mon in enumerate(MONITORS)])
    return "\n".join(lines) + "\n"


def to_jsonl(frame: Telemetry) -> str:
    """JSON-lines event stream: `slot` events (one per slot, every
    scalar series plus the per-cloud dispatch vector), `alert` events
    for tripped monitors, and a terminal `summary` event."""
    _require_lane(frame)
    series = {f: np.asarray(getattr(frame, f)) for f in _SCALAR_SERIES}
    disp = np.asarray(frame.dispatched_cloud)
    active = np.asarray(frame.alert_active)
    T = disp.shape[0]
    out = []
    for t in range(T):
        ev = {"event": "slot", "t": t}
        for f, arr in series.items():
            ev[f] = float(arr[t])
        ev["dispatched_cloud"] = [float(x) for x in disp[t]]
        ev["alerts_active"] = [
            mon for k, mon in enumerate(MONITORS) if active[t, k]
        ]
        out.append(json.dumps(ev))
    tripped = np.asarray(frame.alert_tripped)
    first = np.asarray(frame.alert_first_slot)
    count = np.asarray(frame.alert_count)
    for k, mon in enumerate(MONITORS):
        if tripped[k]:
            out.append(json.dumps({
                "event": "alert", "monitor": mon,
                "first_slot": int(first[k]),
                "slots_active": int(count[k]),
            }))
    summary = {"event": "summary"}
    for spec in _COUNTERS + _GAUGES:
        summary[spec.field] = float(np.asarray(getattr(frame, spec.field)))
    out.append(json.dumps(summary))
    return "\n".join(out) + "\n"


def to_chrome_trace(frame: Telemetry, slot_us: float = 1000.0) -> str:
    """Chrome trace-event JSON: one counter track per scalar series
    (ph="C") and one duration event per contiguous alert window
    (ph="X"), slot t at timestamp t*slot_us. Loads in Perfetto /
    chrome://tracing."""
    _require_lane(frame)
    events = [
        {"name": "process_name", "ph": "M", "pid": 0,
         "args": {"name": "repro.telemetry"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "series"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 1,
         "args": {"name": "alerts"}},
    ]
    for field in _SCALAR_SERIES:
        arr = np.asarray(getattr(frame, field))
        for t in range(arr.shape[0]):
            events.append({
                "name": field, "ph": "C", "pid": 0, "tid": 0,
                "ts": t * slot_us, "args": {field: float(arr[t])},
            })
    active = np.asarray(frame.alert_active)
    for k, mon in enumerate(MONITORS):
        col = active[:, k]
        t = 0
        while t < col.shape[0]:
            if col[t]:
                start = t
                while t < col.shape[0] and col[t]:
                    t += 1
                events.append({
                    "name": f"alert:{mon}", "ph": "X", "cat": "alert",
                    "pid": 0, "tid": 1, "ts": start * slot_us,
                    "dur": (t - start) * slot_us,
                })
            else:
                t += 1
    return json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms"}
    )


def manifest(frame: Telemetry) -> dict:
    """Reduces a Telemetry frame (single-lane or fleet) to the plain
    JSON manifest the bench rows carry: peak backlog (max over lanes),
    emission/waste/failure totals (summed over lanes), and per-monitor
    alert records (lanes tripped, firing-slot total, earliest
    first-trip slot across lanes)."""
    K = len(MONITORS)
    out = {
        "peak_backlog": float(np.max(np.asarray(frame.peak_backlog))),
        "total_emissions": float(
            np.sum(np.asarray(frame.total_emissions))
        ),
        "total_wasted": float(np.sum(np.asarray(frame.total_wasted))),
        "total_failed": float(np.sum(np.asarray(frame.total_failed))),
        "alerts": {},
    }
    tripped = np.asarray(frame.alert_tripped).reshape(-1, K)
    first = np.asarray(frame.alert_first_slot).reshape(-1, K)
    count = np.asarray(frame.alert_count).reshape(-1, K)
    for k, mon in enumerate(MONITORS):
        fs = first[:, k][first[:, k] >= 0]
        out["alerts"][mon] = {
            "tripped": int(tripped[:, k].sum()),
            "slots_active": int(count[:, k].sum()),
            "first_slot": int(fs.min()) if fs.size else -1,
        }
    return out


def oracle_gap_series(result, carbon_table, horizon=None):
    """Per-slot clairvoyant re-pricing of the run's energy profile:
    returns `(oracle_rate [T], gap [T])` float32 where `gap` is the
    realized per-slot emissions minus the windowed-min repriced cost of
    the same energy (the per-slot refinement of
    `core.extensions.oracle_emissions_horizon`: `oracle_rate.sum()`
    equals that bound on the tiled table). For WAN results the transfer
    term stays in `gap` un-repriced -- the oracle covers edge + cloud
    energy only. Host-side numpy on a finished result, like the oracle
    bounds themselves.
    """
    em = np.asarray(result.emissions, np.float64)
    T = em.shape[0]
    ci = np.asarray(carbon_table, np.float64)
    ci = ci[np.arange(T) % ci.shape[0]]
    H = T if horizon is None else int(min(max(horizon, 1), T))
    wmin = ci.copy()
    for h in range(1, H):
        np.minimum(wmin, np.roll(ci, -h, axis=0), out=wmin)
    ee = np.asarray(result.energy_edge, np.float64).reshape(T)
    ec = np.asarray(result.energy_cloud, np.float64).reshape(T, -1)
    oracle = ee * wmin[:, 0] + (ec * wmin[:, 1:]).sum(axis=1)
    return oracle.astype(np.float32), (em - oracle).astype(np.float32)


def write_run(frame: Telemetry, outdir, stem: str = "run") -> dict:
    """Writes all three wire formats for one lane; returns the paths."""
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    paths = {
        "prometheus": outdir / f"{stem}.prom",
        "jsonl": outdir / f"{stem}.jsonl",
        "chrome_trace": outdir / f"{stem}.trace.json",
    }
    paths["prometheus"].write_text(to_prometheus(frame))
    paths["jsonl"].write_text(to_jsonl(frame))
    paths["chrome_trace"].write_text(to_chrome_trace(frame))
    return paths


_PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})?\s+[-+]?"
    r"([0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?|[Nn]a[Nn]|[Ii]nf)$"
)


def validate_prometheus(text: str) -> int:
    """Parse-checks Prometheus text exposition; returns sample count."""
    samples = 0
    typed = set()
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) < 4 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(f"bad comment line {i + 1}: {line!r}")
            if parts[1] == "TYPE":
                typed.add(parts[2])
            continue
        if not _PROM_SAMPLE.match(line):
            raise ValueError(f"bad sample line {i + 1}: {line!r}")
        name = line.split("{")[0].split()[0]
        if name not in typed:
            raise ValueError(f"sample before TYPE for {name!r}")
        samples += 1
    if samples == 0:
        raise ValueError("no samples")
    return samples


def validate_jsonl(text: str) -> int:
    """Parse-checks a JSON-lines event stream; returns event count."""
    events = 0
    kinds = set()
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        ev = json.loads(line)
        if "event" not in ev:
            raise ValueError(f"line {i + 1} missing 'event' field")
        kinds.add(ev["event"])
        events += 1
    if "slot" not in kinds or "summary" not in kinds:
        raise ValueError(f"missing slot/summary events (saw {kinds})")
    return events


def validate_chrome_trace(text: str) -> int:
    """Parse-checks Chrome trace-event JSON; returns event count."""
    doc = json.loads(text)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents missing or empty")
    for i, ev in enumerate(events):
        if "ph" not in ev or "name" not in ev:
            raise ValueError(f"event {i} missing ph/name: {ev!r}")
        if ev["ph"] in ("C", "X") and "ts" not in ev:
            raise ValueError(f"event {i} missing ts: {ev!r}")
    return len(events)


def validate_dir(outdir) -> dict:
    """Validates every telemetry file under `outdir` (the CI
    telemetry-smoke gate); requires at least one file of each format.
    Returns {path: event/sample count}."""
    outdir = Path(outdir)
    checks = {
        "*.prom": validate_prometheus,
        "*.jsonl": validate_jsonl,
        "*.trace.json": validate_chrome_trace,
    }
    out = {}
    for pattern, fn in checks.items():
        paths = sorted(outdir.glob(pattern))
        if not paths:
            raise ValueError(f"no {pattern} files under {outdir}")
        for p in paths:
            out[str(p)] = fn(p.read_text())
    return out
