"""Streaming taps: live TapSeries flushes out of running scans.

The batch taps (taps.py) are effect-free by design: nothing leaves the
device until the compiled call returns. This module is the opt-in
escape hatch for watching a run WHILE it executes. Passing
``telemetry=StreamConfig(flush_every=k)`` to any simulator keeps the
per-slot tap arithmetic bit-identical to a ``TelemetryConfig`` run but
restructures the recording scan into a scan of T//k chunks; after each
chunk one ``jax.experimental.io_callback`` hands the stacked
[k, ...] TapSeries slice (plus lane id and start slot) to a host-side
``StreamChannel``. Consumers subscribe to the channel --
``repro.telemetry.follow_run`` feeds the existing Prometheus/JSONL
exporters from it -- and ``StreamChannel.series`` reassembles the full
[T, ...] TapSeries bitwise-equal to the batch frame.

Contract (DESIGN.md §Live observability):

* values never change -- the scan body is the same `step_taps` program,
  chunking reuses the stride-recording structure `_record_scan` already
  proves bitwise-neutral, and the callback only *reads* the slice;
* the flush is UNCONDITIONAL, once per chunk. A data-dependent
  (`lax.cond`-gated) flush would put an IO effect inside `cond`, which
  `vmap` (the fleet path) cannot batch; an unconditional callback
  vmaps by expanding to one host call per lane, which is exactly the
  per-lane delivery we want. Lanes carry an explicit `lane` tag in the
  payload because the vmapped callback sees unbatched slices;
* the streamed program is NOT effect-free. The jaxpr auditor only
  tolerates `io_callback` on combos named in
  `analysis.audit.EFFECTFUL_ALLOWLIST`; every other path must still
  trace callback-free, so streaming can never leak into a default run.

Callbacks may fire from XLA runtime threads: `StreamChannel` locks its
buffer, and subscribers must be thread-safe (appending to a file is).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np
from jax.experimental import io_callback

from repro.telemetry.taps import TapSeries, TelemetryConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Opt-in streaming telemetry. Frozen + hashable like
    TelemetryConfig: the whole config is a trace-time static, and two
    equal configs trace the same program.

    taps         the TelemetryConfig the in-scan taps run with (the
                 streamed values are ITS TapSeries, untouched)
    flush_every  slots per io_callback flush; must divide T. Larger
                 values amortize the host hop -- the committed bench
                 row holds the <10% overhead budget at >=16
    channel      name of the host StreamChannel flushes land on
    capacity     max buffered slices the channel retains (ring buffer;
                 oldest dropped first). Subscribers see every flush
                 regardless -- capacity only bounds replay memory.
    """

    taps: TelemetryConfig = TelemetryConfig()
    flush_every: int = 16
    channel: str = "default"
    capacity: int = 4096

    def __post_init__(self):
        if self.flush_every < 1:
            raise ValueError(
                f"flush_every={self.flush_every} must be >= 1"
            )


def split_telemetry(telemetry):
    """Normalizes a simulator's `telemetry` argument into
    (TelemetryConfig | None, StreamConfig | None): plain configs run
    batch-only, StreamConfig runs its `.taps` config plus flushes."""
    if telemetry is None:
        return None, None
    if isinstance(telemetry, StreamConfig):
        return telemetry.taps, telemetry
    return telemetry, None


class StreamChannel:
    """Host-side landing zone for one stream of flushed slices.

    Thread-safe: `push` runs inside io_callback on runtime threads.
    Slices are kept (up to `capacity`, oldest dropped) for replay via
    `series`; subscribers are invoked synchronously on every push.
    """

    def __init__(self, name: str, capacity: int = 4096):
        self.name = name
        self.capacity = capacity
        self._lock = threading.Lock()
        self._slices: List[Tuple[int, int, TapSeries]] = []
        self._subscribers: List[Callable] = []
        self.flushes = 0
        self.dropped = 0

    def subscribe(self, fn: Callable) -> Callable:
        """Registers fn(lane, t0, slice_) on every flush; returns fn."""
        with self._lock:
            self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn: Callable) -> None:
        with self._lock:
            if fn in self._subscribers:
                self._subscribers.remove(fn)

    def push(self, lane: int, t0: int, slice_: TapSeries) -> None:
        with self._lock:
            self.flushes += 1
            self._slices.append((lane, t0, slice_))
            while len(self._slices) > self.capacity:
                self._slices.pop(0)
                self.dropped += 1
            subs = list(self._subscribers)
        for fn in subs:
            fn(lane, t0, slice_)

    def clear(self) -> None:
        with self._lock:
            self._slices.clear()
            self.flushes = 0
            self.dropped = 0

    def lanes(self) -> List[int]:
        with self._lock:
            return sorted({lane for lane, _, _ in self._slices})

    def series(self, lane: int = 0) -> TapSeries:
        """Reassembles the buffered slices of one lane into the full
        [T, ...] TapSeries, ordered by start slot -- bitwise equal to
        the batch frame's series when no slice was dropped."""
        with self._lock:
            got = sorted(
                (t0, s) for ln, t0, s in self._slices if ln == lane
            )
        if not got:
            raise ValueError(
                f"channel {self.name!r} holds no slices for lane {lane} "
                f"(lanes seen: {self.lanes()})"
            )
        return TapSeries(*(
            np.concatenate([np.asarray(getattr(s, f)) for _, s in got])
            for f in TapSeries._fields
        ))


_CHANNELS: Dict[str, StreamChannel] = {}
_CHANNELS_LOCK = threading.Lock()
# One emit closure per channel name, cached so repeated traces of the
# same StreamConfig close over the identical callable (jit-cache and
# retrace-audit friendly).
_EMITTERS: Dict[str, Callable] = {}


def channel(name: str = "default",
            capacity: int = 4096) -> StreamChannel:
    """Returns (creating on first use) the named StreamChannel."""
    with _CHANNELS_LOCK:
        ch = _CHANNELS.get(name)
        if ch is None:
            ch = _CHANNELS[name] = StreamChannel(name, capacity)
        return ch


def reset_channel(name: str = "default") -> StreamChannel:
    """Clears the named channel's buffer and counters (subscribers
    stay); the idiom at the top of every streaming run."""
    ch = channel(name)
    ch.clear()
    return ch


def _emitter(name: str) -> Callable:
    with _CHANNELS_LOCK:
        fn = _EMITTERS.get(name)
        if fn is None:
            def fn(lane, t0, slice_):
                channel(name).push(
                    int(lane), int(t0), jax.tree.map(np.asarray, slice_)
                )
            _EMITTERS[name] = fn
        return fn


def stream_flush(cfg: StreamConfig, lane, t0, slice_: TapSeries) -> None:
    """Called INSIDE the compiled chunk scan: hands the stacked
    [flush_every, ...] TapSeries slice to the host channel. Unordered
    (`ordered=True` cannot vmap, and the fleet path vmaps this), so
    consumers must key on the payload's (lane, t0) -- slices may arrive
    out of order and every event carries its slot index."""
    channel(cfg.channel, cfg.capacity)  # exists before first flush
    io_callback(_emitter(cfg.channel), None, lane, t0, slice_)


__all__ = [
    "StreamConfig",
    "StreamChannel",
    "channel",
    "reset_channel",
    "split_telemetry",
    "stream_flush",
]
