"""In-scan SLO health monitors.

Each monitor is a per-slot threshold condition evaluated INSIDE the
scan body on the current `TelemetryProbe` (plus the small carried tap
state) -- no host callback ever fires. The [K] int32 activity vector is
emitted as a per-slot series; `finalize_taps` reduces the stacked
[T, K] matrix into structured alert records (tripped flag, first-trip
slot index, active-slot count) after the compiled call returns.

The registry order is the alert axis: `Telemetry.alert_active[:, k]`,
`alert_first_slot[k]` etc. all index `MONITORS[k]`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# Alert axis. Keep descriptions in sync with DESIGN.md §Observability.
MONITORS = (
    # backlog grew by more than growth_thresh for growth_sustain
    # consecutive slots: the system is losing the stability race.
    "backlog_growth",
    # the carbon signal the policy acts on is older than stale_budget
    # slots (beyond what StalenessGuardPolicy is tuned to absorb).
    "signal_staleness",
    # every cloud reports zero capacity: nothing the policy dispatches
    # can be serviced this slot.
    "all_clouds_down",
    # the flow-conservation residual
    #   cum(arrived) - (backlog + cum(processed) - cum(failed))
    #                - cum(missed) - cum(shed)
    # left the +/- drift_tol band: the ledger is leaking tasks.
    "conservation_drift",
    # tasks expired past their deadline this slot (beyond miss_tol):
    # the scheduler is converting deferral into SLO violations.
    "deadline_miss",
    # admission control rejected more than shed_frac of this slot's
    # arrivals: the system is in sustained overload.
    "shed_rate",
)
K = len(MONITORS)


def monitor_conditions(cfg, probe, growth_run: Array,
                       residual: Array) -> Array:
    """[K] int32 vector of per-slot alert conditions (1 = firing).

    `growth_run` is the carried count of consecutive growth slots
    (already including this slot); `residual` the carried conservation
    residual after this slot. Everything else comes off the probe.
    """
    n_clouds = probe.dispatched.shape[0]
    conds = (
        growth_run >= cfg.growth_sustain,
        probe.stale > cfg.stale_budget,
        probe.clouds_down >= jnp.float32(n_clouds),
        jnp.abs(residual) > cfg.drift_tol,
        probe.missed > cfg.miss_tol,
        probe.shed > cfg.shed_frac * probe.arrived,
    )
    return jnp.stack([c.astype(jnp.int32) for c in conds])
