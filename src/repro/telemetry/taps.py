"""Metrics taps: the in-scan telemetry state machine.

Design constraints (the audit enforces all of them):

* pure JAX, no host callbacks -- every metric is either a per-slot
  scan output (`TapSeries`) or a scan-carried f32/int32 accumulator
  (`TapState`); export happens host-side after the compiled call.
* `telemetry=None` runs are bit-identical to pre-telemetry simulators:
  the tap carry element is `()` (zero pytree leaves) and the scan body
  is untouched, so the jaxpr is the same program.
* record-mode independence: `TapSeries` rides the scalar output path of
  `_record_scan`, which is identical in "full" / "summary" / stride
  mode, so the whole `Telemetry` frame is bitwise-equal across modes.

Per-simulator wiring: each scan body builds a `TelemetryProbe` from
values it already computes (fields that do not apply are pinned zeros
-- e.g. `retry_depth` in the fault-free simulators), calls
`step_taps`, and appends the returned `TapSeries` to its outputs;
`finalize_taps` turns the stacked series into the `Telemetry` frame
attached to the result's `telemetry` field.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.telemetry.monitors import MONITORS, monitor_conditions

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Monitor thresholds. Frozen + hashable: the config is a static
    (trace-time) value -- close over it or mark it static under jit;
    two configs hash equal iff they trace the same program.

    growth_thresh   backlog delta per slot that counts as "growing"
    growth_sustain  consecutive growing slots before the alert trips
    stale_budget    carbon-signal age (slots) the run tolerates
    drift_tol       |conservation residual| tolerance (tasks)
    miss_tol        deadline misses per slot the SLO tolerates
    shed_frac       shed fraction of arrivals the SLO tolerates
    """

    growth_thresh: float = 0.0
    growth_sustain: int = 8
    stale_budget: int = 4
    drift_tol: float = 0.5
    miss_tol: float = 0.0
    shed_frac: float = 0.0


class TelemetryProbe(NamedTuple):
    """What one slot exposes to the taps. Scalars f32 unless noted;
    simulators pin fields that do not apply to `jnp.float32(0.0)` /
    `jnp.int32(0)` so dtype discipline holds across all bodies."""

    emissions: Array           # C(t) at true intensities
    arrived: Array             # tasks arriving at the edge
    dispatched: Array          # [N] tasks landing in each cloud queue
    processed: Array           # processing attempts (post service mask)
    failed: Array              # attempts failed into the retry pool
    wasted: Array              # carbon spent on failed attempts
    backlog: Array             # post-step Qe+Qc[+Qt][+retry] total
    stale: Array               # int32 carbon-signal age seen by policy
    clouds_down: Array         # clouds at zero capacity this slot
    retry_depth: Array         # retry-pool total (post-step)
    transfer_occupancy: Array  # in-flight transfer queue total
    # Deadline-layer fields default to exact zeros so the pre-deadline
    # probe construction sites (and deadline-off runs) stay untouched.
    missed: Array = jnp.float32(0.0)  # tasks expired past deadline
    shed: Array = jnp.float32(0.0)    # arrivals rejected by admission


class TapState(NamedTuple):
    """The scan-carried accumulators (f32/int32 scalars only)."""

    prev_backlog: Array   # f32, for the growth-rate series
    growth_run: Array     # int32 consecutive-growth counter
    cum_arrived: Array    # f32 running totals for the
    cum_processed: Array  # f32   conservation residual
    cum_failed: Array     # f32
    cum_missed: Array     # f32
    cum_shed: Array       # f32


class TapSeries(NamedTuple):
    """Per-slot tap outputs (stacked to [T, ...] by the scan)."""

    emission_rate: Array          # f32
    arrived: Array                # f32
    dispatched_cloud: Array       # [N] f32
    processed: Array              # f32
    failed: Array                 # f32
    wasted: Array                 # f32
    backlog: Array                # f32
    backlog_growth: Array         # f32 backlog delta vs previous slot
    staleness: Array              # int32
    clouds_down: Array            # f32
    retry_depth: Array            # f32
    transfer_occupancy: Array     # f32
    missed: Array                 # f32 deadline expiries this slot
    shed: Array                   # f32 arrivals shed this slot
    conservation_residual: Array  # f32
    alert_active: Array           # [K] int32, axis = monitors.MONITORS


class Telemetry(NamedTuple):
    """The exported frame: `TapSeries` stacked over T plus run-level
    gauges/counters and the structured alert records. Under
    `simulate_fleet` every field carries a leading [F] axis (see
    `lane`)."""

    # per-slot series [T, ...]
    emission_rate: Array
    arrived: Array
    dispatched_cloud: Array       # [T, N]
    processed: Array
    failed: Array
    wasted: Array
    backlog: Array
    backlog_growth: Array
    staleness: Array              # [T] int32
    clouds_down: Array
    retry_depth: Array
    transfer_occupancy: Array
    missed: Array
    shed: Array
    conservation_residual: Array
    alert_active: Array           # [T, K] int32
    # run gauges / counters (f32 scalars)
    peak_backlog: Array
    total_emissions: Array
    total_arrived: Array
    total_processed: Array
    total_failed: Array
    total_wasted: Array
    total_missed: Array
    total_shed: Array
    # structured alert records ([K] int32, axis = monitors.MONITORS)
    alert_tripped: Array
    alert_first_slot: Array       # first firing slot, -1 = never
    alert_count: Array            # number of firing slots


def init_taps() -> TapState:
    return TapState(
        prev_backlog=jnp.float32(0.0),
        growth_run=jnp.int32(0),
        cum_arrived=jnp.float32(0.0),
        cum_processed=jnp.float32(0.0),
        cum_failed=jnp.float32(0.0),
        cum_missed=jnp.float32(0.0),
        cum_shed=jnp.float32(0.0),
    )


def step_taps(cfg: TelemetryConfig, tap: TapState,
              probe: TelemetryProbe) -> tuple:
    """One slot of tap accounting: (TapState, TapSeries)."""
    growth = probe.backlog - tap.prev_backlog
    growth_run = jnp.where(
        growth > cfg.growth_thresh,
        tap.growth_run + jnp.int32(1),
        jnp.int32(0),
    )
    cum_arrived = tap.cum_arrived + probe.arrived
    cum_processed = tap.cum_processed + probe.processed
    cum_failed = tap.cum_failed + probe.failed
    cum_missed = tap.cum_missed + probe.missed
    cum_shed = tap.cum_shed + probe.shed
    # The trailing subtractions are exact -0.0 no-ops in deadline-off
    # runs (the cums stay +0.0), preserving the pre-deadline residual
    # bit-for-bit.
    residual = cum_arrived - (
        probe.backlog + cum_processed - cum_failed
    ) - cum_missed - cum_shed
    active = monitor_conditions(cfg, probe, growth_run, residual)
    nxt = TapState(
        prev_backlog=probe.backlog,
        growth_run=growth_run,
        cum_arrived=cum_arrived,
        cum_processed=cum_processed,
        cum_failed=cum_failed,
        cum_missed=cum_missed,
        cum_shed=cum_shed,
    )
    series = TapSeries(
        emission_rate=probe.emissions,
        arrived=probe.arrived,
        dispatched_cloud=probe.dispatched,
        processed=probe.processed,
        failed=probe.failed,
        wasted=probe.wasted,
        backlog=probe.backlog,
        backlog_growth=growth,
        staleness=probe.stale,
        clouds_down=probe.clouds_down,
        retry_depth=probe.retry_depth,
        transfer_occupancy=probe.transfer_occupancy,
        missed=probe.missed,
        shed=probe.shed,
        conservation_residual=residual,
        alert_active=active,
    )
    return nxt, series


def finalize_taps(cfg: TelemetryConfig, series: TapSeries) -> Telemetry:
    """Reduces the stacked [T, ...] series into the Telemetry frame.

    Pure functions of the series (which `_record_scan` records
    identically in every mode), so the frame is bitwise-equal across
    "full" / "summary" / stride runs. Reductions pin int32 explicitly:
    under the audit's x64 re-trace, integer sums/argmax default to
    64-bit otherwise.
    """
    active = series.alert_active                      # [T, K] int32
    count = jnp.sum(active, axis=0).astype(jnp.int32)
    tripped = (count > 0).astype(jnp.int32)
    first = jnp.where(
        count > 0,
        jnp.argmax(active, axis=0).astype(jnp.int32),
        jnp.int32(-1),
    )
    return Telemetry(
        emission_rate=series.emission_rate,
        arrived=series.arrived,
        dispatched_cloud=series.dispatched_cloud,
        processed=series.processed,
        failed=series.failed,
        wasted=series.wasted,
        backlog=series.backlog,
        backlog_growth=series.backlog_growth,
        staleness=series.staleness,
        clouds_down=series.clouds_down,
        retry_depth=series.retry_depth,
        transfer_occupancy=series.transfer_occupancy,
        missed=series.missed,
        shed=series.shed,
        conservation_residual=series.conservation_residual,
        alert_active=active,
        peak_backlog=jnp.max(series.backlog),
        total_emissions=jnp.sum(series.emission_rate),
        total_arrived=jnp.sum(series.arrived),
        total_processed=jnp.sum(series.processed),
        total_failed=jnp.sum(series.failed),
        total_wasted=jnp.sum(series.wasted),
        total_missed=jnp.sum(series.missed),
        total_shed=jnp.sum(series.shed),
        alert_tripped=tripped,
        alert_first_slot=first,
        alert_count=count,
    )


def lane(frame: Telemetry, i: int) -> Telemetry:
    """Selects lane i of a fleet Telemetry frame ([F, ...] -> [...])."""
    return jax.tree.map(lambda x: x[i], frame)


class MetricSpec(NamedTuple):
    """Registry row: how a Telemetry field exports."""

    field: str  # Telemetry field name
    kind: str   # "series" | "gauge" | "counter"
    unit: str
    help: str


# The typed registry the exporters iterate. Alert fields are exported
# separately (one labelled metric per monitor in MONITORS).
METRICS = (
    MetricSpec("emission_rate", "series", "gCO2/slot",
               "per-slot carbon emissions at true intensities"),
    MetricSpec("arrived", "series", "tasks/slot",
               "tasks arriving at the edge"),
    MetricSpec("dispatched_cloud", "series", "tasks/slot",
               "tasks landing in each cloud queue"),
    MetricSpec("processed", "series", "tasks/slot",
               "processing attempts (post service mask)"),
    MetricSpec("failed", "series", "tasks/slot",
               "attempts failed into the retry pool"),
    MetricSpec("wasted", "series", "gCO2/slot",
               "carbon spent on failed attempts"),
    MetricSpec("backlog", "series", "tasks",
               "post-step total backlog Qe+Qc[+Qt][+retry]"),
    MetricSpec("backlog_growth", "series", "tasks/slot",
               "backlog delta vs previous slot"),
    MetricSpec("staleness", "series", "slots",
               "carbon-signal age seen by the policy"),
    MetricSpec("clouds_down", "series", "clouds",
               "clouds at zero capacity"),
    MetricSpec("retry_depth", "series", "tasks",
               "retry-pool total"),
    MetricSpec("transfer_occupancy", "series", "tasks",
               "in-flight WAN transfer total"),
    MetricSpec("missed", "series", "tasks/slot",
               "tasks expired past their deadline"),
    MetricSpec("shed", "series", "tasks/slot",
               "arrivals rejected by admission control"),
    MetricSpec("conservation_residual", "series", "tasks",
               "flow-conservation residual (should be ~0)"),
    MetricSpec("peak_backlog", "gauge", "tasks",
               "max backlog over the run"),
    MetricSpec("total_emissions", "counter", "gCO2",
               "cumulative carbon over the run"),
    MetricSpec("total_arrived", "counter", "tasks",
               "tasks arrived over the run"),
    MetricSpec("total_processed", "counter", "tasks",
               "processing attempts over the run"),
    MetricSpec("total_failed", "counter", "tasks",
               "failed attempts over the run"),
    MetricSpec("total_wasted", "counter", "gCO2",
               "carbon wasted on failed attempts over the run"),
    MetricSpec("total_missed", "counter", "tasks",
               "deadline misses over the run"),
    MetricSpec("total_shed", "counter", "tasks",
               "arrivals shed over the run"),
)

__all__ = [
    "MONITORS",
    "METRICS",
    "MetricSpec",
    "TelemetryConfig",
    "TelemetryProbe",
    "TapState",
    "TapSeries",
    "Telemetry",
    "init_taps",
    "step_taps",
    "finalize_taps",
    "lane",
]
