"""Telemetry: in-scan metrics taps, phase annotation, SLO monitors,
and host-side exporters (DESIGN.md §Observability).

Turn it on by passing `telemetry=TelemetryConfig()` to any simulator
(`simulate`, `simulate_network`, `simulate_faulted`,
`simulate_network_faulted`, `simulate_fleet`); the result's
`.telemetry` field then carries a `Telemetry` frame of per-slot series,
run gauges, and structured alert records. `telemetry=None` (the
default) is bit-identical to a build without this package.
"""
from repro.telemetry.export import (
    manifest,
    oracle_gap_series,
    to_chrome_trace,
    to_jsonl,
    to_prometheus,
    validate_chrome_trace,
    validate_dir,
    validate_jsonl,
    validate_prometheus,
    write_run,
)
from repro.telemetry.monitors import MONITORS, monitor_conditions
from repro.telemetry.profile import PHASES, phase, trace_to
from repro.telemetry.taps import (
    METRICS,
    MetricSpec,
    TapSeries,
    TapState,
    Telemetry,
    TelemetryConfig,
    TelemetryProbe,
    finalize_taps,
    init_taps,
    lane,
    step_taps,
)

__all__ = [
    "MONITORS",
    "METRICS",
    "PHASES",
    "MetricSpec",
    "TapSeries",
    "TapState",
    "Telemetry",
    "TelemetryConfig",
    "TelemetryProbe",
    "finalize_taps",
    "init_taps",
    "lane",
    "manifest",
    "monitor_conditions",
    "oracle_gap_series",
    "phase",
    "step_taps",
    "to_chrome_trace",
    "to_jsonl",
    "to_prometheus",
    "trace_to",
    "validate_chrome_trace",
    "validate_dir",
    "validate_jsonl",
    "validate_prometheus",
    "write_run",
]
