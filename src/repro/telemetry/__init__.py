"""Telemetry: in-scan metrics taps, phase annotation, SLO monitors,
and host-side exporters (DESIGN.md §Observability).

Turn it on by passing `telemetry=TelemetryConfig()` to any simulator
(`simulate`, `simulate_network`, `simulate_faulted`,
`simulate_network_faulted`, `simulate_fleet`); the result's
`.telemetry` field then carries a `Telemetry` frame of per-slot series,
run gauges, and structured alert records. `telemetry=None` (the
default) is bit-identical to a build without this package.

Live mode: pass `telemetry=StreamConfig(flush_every=k)` instead and
attach a `follow_run` consumer -- TapSeries slices flush to a host
StreamChannel every k slots WHILE the scan runs, feeding the same
Prometheus/JSONL formats incrementally (DESIGN.md §Live observability;
the traced program then carries an io_callback and must be on the
jaxpr audit's effectful allowlist).
"""
from repro.telemetry.export import (
    FollowedRun,
    follow_run,
    manifest,
    oracle_gap_series,
    to_chrome_trace,
    to_jsonl,
    to_prometheus,
    validate_chrome_trace,
    validate_dir,
    validate_jsonl,
    validate_prometheus,
    write_run,
)
from repro.telemetry.monitors import MONITORS, monitor_conditions
from repro.telemetry.profile import PHASES, phase, trace_to
from repro.telemetry.stream import (
    StreamChannel,
    StreamConfig,
    channel,
    reset_channel,
    split_telemetry,
)
from repro.telemetry.taps import (
    METRICS,
    MetricSpec,
    TapSeries,
    TapState,
    Telemetry,
    TelemetryConfig,
    TelemetryProbe,
    finalize_taps,
    init_taps,
    lane,
    step_taps,
)

__all__ = [
    "MONITORS",
    "METRICS",
    "PHASES",
    "FollowedRun",
    "MetricSpec",
    "StreamChannel",
    "StreamConfig",
    "TapSeries",
    "TapState",
    "Telemetry",
    "TelemetryConfig",
    "TelemetryProbe",
    "channel",
    "finalize_taps",
    "follow_run",
    "init_taps",
    "lane",
    "manifest",
    "reset_channel",
    "split_telemetry",
    "monitor_conditions",
    "oracle_gap_series",
    "phase",
    "step_taps",
    "to_chrome_trace",
    "to_jsonl",
    "to_prometheus",
    "trace_to",
    "validate_chrome_trace",
    "validate_dir",
    "validate_jsonl",
    "validate_prometheus",
    "write_run",
]
