"""Deterministic synthetic data pipeline.

Production trainers stream tokenized shards; offline we generate
reproducible token streams with a counter-based PRNG so that (a) every
host/shard slices the same logical stream without coordination, (b)
checkpoint-restart resumes mid-stream bit-exactly (the step index IS the
cursor), and (c) each task type (architecture) gets an independent stream.

Also provides the task-arrival processes that feed the GreenOrchestrator
(the a_m(t) of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Infinite synthetic LM stream: batch(step) is a pure function."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so losses are learnable, not pure noise
    n_patterns: int = 64
    pattern_len: int = 16

    def batch(self, step: int) -> Dict[str, Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S = self.global_batch, self.seq_len
        # each sequence interleaves a repeated pattern with noise tokens:
        # next-token prediction has signal (the repeats) => loss decreases.
        pat_ids = jax.random.randint(k1, (B, 1), 0, self.n_patterns)
        base = jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), 0),
            (self.n_patterns, self.pattern_len), 0, self.vocab_size,
        )
        reps = (S + self.pattern_len - 1) // self.pattern_len
        pattern = jnp.tile(base[pat_ids[:, 0]], (1, reps))[:, :S]
        noise = jax.random.randint(k2, (B, S), 0, self.vocab_size)
        is_noise = jax.random.bernoulli(k3, 0.15, (B, S))
        tokens = jnp.where(is_noise, noise, pattern).astype(jnp.int32)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1
        )
        return {"tokens": tokens, "labels": labels}

    def shard_for_host(self, batch: Dict[str, Array], host: int,
                       n_hosts: int) -> Dict[str, Array]:
        assert self.global_batch % n_hosts == 0
        per = self.global_batch // n_hosts
        return jax.tree.map(lambda x: x[host * per : (host + 1) * per], batch)


def make_batch_fn(cfg, seq_len: int, global_batch: int, seed: int = 0):
    """Batch function for any architecture family (stub frontends get
    random embeddings, consistent with input_specs)."""
    stream = TokenStream(cfg.vocab_size, seq_len, global_batch, seed)

    def batch(step: int) -> Dict[str, Array]:
        b = stream.batch(step)
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
        if cfg.is_encoder_decoder:
            frames = jax.random.normal(
                key, (global_batch, cfg.source_len, cfg.d_model),
                jnp.float32,
            ) * 0.02
            return {"frames": frames, "tokens": b["tokens"],
                    "labels": b["labels"]}
        if cfg.family == "vlm":
            s_text = seq_len - cfg.prefix_len
            patches = jax.random.normal(
                key, (global_batch, cfg.prefix_len, cfg.d_model), jnp.float32
            ) * 0.02
            return {
                "patches": patches,
                "tokens": b["tokens"][:, :s_text],
                "labels": b["labels"][:, :s_text],
            }
        return b

    return batch


@dataclasses.dataclass(frozen=True)
class TaskArrivals:
    """a_m(t) ~ U{0..amax} (paper §V) over M task types; pure in (seed,t)."""

    M: int
    amax: int = 400
    seed: int = 0

    def __call__(self, t: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, t))
        return rng.integers(0, self.amax + 1, self.M).astype(np.float32)
