"""Fault processes for the queueing network: pure-JAX, scan-carried.

Four orthogonal fault axes, each a per-slot stochastic process whose
state threads through the simulation carry (so fleets vmap fault
scenarios across lanes in one compiled call):

  * cloud outages   -- per-cloud Markov on/off chain (p_down/p_up) plus
    a deterministic scheduled-blackout window (sched_start/sched_len,
    in slots) for reproducible regional-blackout experiments;
  * brownouts       -- a second per-cloud chain that scales the cloud's
    energy budget by `brown_floor` while active (partial capacity);
  * link flaps      -- per-route Markov chain scaling link bandwidth by
    `link_floor` while down (0 = hard flap), for repro.network runs;
  * telemetry dropouts -- a scalar chain on the carbon feed: while down
    the policy sees the LAST GOOD intensity row and an explicit
    staleness counter; emissions are always accounted at TRUE
    intensities (stale telemetry can mislead the policy, never the
    ledger);
  * task failures   -- each processed task fails with `task_p_fail` at
    its cloud; failed work re-enters the system through a bounded
    exponential-backoff retry pool (spent energy is charged as wasted
    emissions by the simulator).

Integral task counts are preserved by stochastic rounding:
`floor(x + U)` with U ~ Uniform[0,1) is integral, mean-exact
(E = x) and never exceeds the integral pool it draws from -- the same
trick the fleet arrival draw uses.

The zero-fault anchor: with `no_faults(...)` every chain stays in its
"up" state and every mask is an exact 1.0 / +0.0, so the faulted
simulator's arithmetic reduces to bitwise identities (x * 1.0, x + 0.0)
and trajectories match the fault-free simulator bit-for-bit on both
score backends (tests/test_faults.py asserts this).

All carry leaves are float32 / int32 / bool (the analysis.audit carry
discipline); every random draw pins its dtype so the x64 re-trace
stays clean.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.telemetry.profile import phase

Array = jax.Array

# Salt for deriving the fault PRNG stream from the simulation key via
# fold_in: the existing (carbon, arrival, policy) streams come from
# jax.random.split(key, 3) and stay bit-identical whether or not faults
# are enabled.
FAULT_STREAM_SALT = 7


class FaultParams(NamedTuple):
    """Fault-process rates. A pytree of float32 arrays so fleets stack
    it on a leading axis and vmap; the three link fields are None when
    simulating without a LinkGraph (None is treedef, not a leaf)."""

    cloud_p_down: Array   # [N] P(up -> down) per slot
    cloud_p_up: Array     # [N] P(down -> up) per slot
    brown_p_start: Array  # [N] P(enter brownout)
    brown_p_end: Array    # [N] P(exit brownout)
    brown_floor: Array    # [N] capacity factor while browned, in (0, 1]
    sched_start: Array    # [N] scheduled blackout start slot
    sched_len: Array      # [N] scheduled blackout length (0 = none)
    task_p_fail: Array    # [N] per-task failure probability at cloud n
    backoff_max: Array    # [] max retry backoff level (release ~ 2^-lvl)
    telem_p_down: Array   # [] P(carbon feed drops)
    telem_p_up: Array     # [] P(carbon feed recovers)
    link_p_down: Array | None = None  # [L] P(link flaps down)
    link_p_up: Array | None = None    # [L] P(link recovers)
    link_floor: Array | None = None   # [L] bw factor while flapped


class FaultState(NamedTuple):
    """Scan-carried fault state (dtypes per the audit carry rules)."""

    cloud_up: Array   # [N] bool Markov outage chain
    browned: Array    # [N] bool brownout chain
    telem_up: Array   # []  bool telemetry chain
    last_row: Array   # [N+1] float32 last good intensity row
    stale: Array      # []  int32 slots since a fresh carbon reading
    retry: Array      # [M, N] float32 failed tasks awaiting requeue
    backoff: Array    # [N] int32 retry backoff level
    link_up: Array | None = None  # [L] bool link chain


class FaultView(NamedTuple):
    """What one slot of fault state exposes to the policy/simulator."""

    obs_row: Array    # [N+1] observed (possibly stale) intensity row
    stale: Array      # []  int32 staleness of obs_row
    cloud_cap: Array  # [N] capacity factor (0 down, brown_floor, or 1)
    cloud_on: Array   # [N] 1.0 where the cloud can process at all
    released: Array   # [M, N] retry tasks re-entering Qc this slot
    bw_scale: Array | None = None  # [L] bandwidth factor (1.0 = clean)
    link_on: Array | None = None   # [L] 1.0 where the route is usable


def no_faults(N: int, L: int | None = None) -> FaultParams:
    """All rates zero, all floors 1.0: the bitwise-parity anchor."""
    z = jnp.zeros((N,), jnp.float32)
    o = jnp.ones((N,), jnp.float32)
    s = jnp.zeros((), jnp.float32)
    return FaultParams(
        cloud_p_down=z, cloud_p_up=z,
        brown_p_start=z, brown_p_end=z, brown_floor=o,
        sched_start=z, sched_len=z,
        task_p_fail=z,
        backoff_max=jnp.asarray(6.0, jnp.float32),
        telem_p_down=s, telem_p_up=s,
        link_p_down=None if L is None else jnp.zeros((L,), jnp.float32),
        link_p_up=None if L is None else jnp.zeros((L,), jnp.float32),
        link_floor=None if L is None else jnp.ones((L,), jnp.float32),
    )


def make_faults(N: int, L: int | None = None, **overrides) -> FaultParams:
    """`no_faults` with per-field overrides, scalars broadcast to the
    field's shape -- the one constructor scenario builders and tests
    use so shapes/dtypes can't drift."""
    base = no_faults(N, L)
    bad = set(overrides) - set(FaultParams._fields)
    if bad:
        raise ValueError(f"unknown FaultParams fields: {sorted(bad)}")
    cast = {
        k: jnp.broadcast_to(
            jnp.asarray(v, jnp.float32), getattr(base, k).shape
        )
        for k, v in overrides.items()
        if getattr(base, k) is not None
    }
    missing = [k for k in overrides if getattr(base, k) is None]
    if missing:
        raise ValueError(
            f"link fault fields {missing} need L (got L=None): pass the "
            "route count when building faults for a LinkGraph run"
        )
    return base._replace(**cast)


def stack_faults(params: list) -> FaultParams:
    """Stacks per-lane FaultParams onto a leading fleet axis (None link
    fields must be None in every lane)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params)


def init_faults(M: int, N: int, L: int | None = None) -> FaultState:
    return FaultState(
        cloud_up=jnp.ones((N,), bool),
        browned=jnp.zeros((N,), bool),
        telem_up=jnp.ones((), bool),
        last_row=jnp.zeros((N + 1,), jnp.float32),
        stale=jnp.zeros((), jnp.int32),
        retry=jnp.zeros((M, N), jnp.float32),
        backoff=jnp.zeros((N,), jnp.int32),
        link_up=None if L is None else jnp.ones((L,), bool),
    )


def _stoch_round(x: Array, key: Array) -> Array:
    """Integral stochastic rounding: E[out] = x, out <= the integral
    pool x was scaled from (U < 1 strictly)."""
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return jnp.floor(x + u)


def step_faults(
    fs: FaultState,
    fp: FaultParams,
    t: Array,
    key: Array,
    true_row: Array,
) -> Tuple[FaultState, FaultView]:
    """Advances every fault chain one slot and builds the slot's view.

    Order inside a slot: chains transition first (so a cloud that drops
    at slot t is already unavailable to slot t's policy), telemetry
    freezes/refreshes the observed row, then the retry pool releases
    `floor(retry * 2^-backoff * on + U)` tasks per (type, cloud) back
    toward Qc -- gated on the cloud being up, so a recovering cloud is
    re-fed gradually instead of all at once. Failures from this slot's
    processing are added afterwards by `requeue_failed`.

    The phase scope labels the fault step in profiler traces
    (repro.telemetry §profiling, metadata only).
    """
    with phase("fault_step"):
        return _step_faults(fs, fp, t, key, true_row)


def _step_faults(fs, fp, t, key, true_row):
    k_cloud, k_brown, k_telem, k_link, k_rel = jax.random.split(key, 5)
    N = fp.cloud_p_down.shape[0]

    u = jax.random.uniform(k_cloud, (N,), dtype=jnp.float32)
    cloud_up = jnp.where(fs.cloud_up, u >= fp.cloud_p_down,
                         u < fp.cloud_p_up)
    ub = jax.random.uniform(k_brown, (N,), dtype=jnp.float32)
    browned = jnp.where(fs.browned, ub >= fp.brown_p_end,
                        ub < fp.brown_p_start)
    tf = t.astype(jnp.float32)
    sched_down = (tf >= fp.sched_start) & (
        tf < fp.sched_start + fp.sched_len
    )
    cloud_cap = jnp.where(
        sched_down | ~cloud_up,
        0.0,
        jnp.where(browned, fp.brown_floor, 1.0),
    )
    cloud_on = (cloud_cap > 0.0).astype(jnp.float32)

    ut = jax.random.uniform(k_telem, (), dtype=jnp.float32)
    telem_up = jnp.where(fs.telem_up, ut >= fp.telem_p_down,
                         ut < fp.telem_p_up)
    obs_row = jnp.where(telem_up, true_row, fs.last_row)
    stale = jnp.where(telem_up, jnp.int32(0), fs.stale + 1)

    if fp.link_p_down is not None:
        L = fp.link_p_down.shape[0]
        ul = jax.random.uniform(k_link, (L,), dtype=jnp.float32)
        link_up = jnp.where(fs.link_up, ul >= fp.link_p_down,
                            ul < fp.link_p_up)
        bw_scale = jnp.where(link_up, 1.0, fp.link_floor)
        link_on = (bw_scale > 0.0).astype(jnp.float32)
    else:
        link_up, bw_scale, link_on = None, None, None

    rate = jnp.exp2(-fs.backoff.astype(jnp.float32))  # [N]
    released = _stoch_round(
        fs.retry * (rate * cloud_on)[None, :], k_rel
    )

    nxt = FaultState(
        cloud_up=cloud_up,
        browned=browned,
        telem_up=telem_up,
        last_row=obs_row,
        stale=stale,
        retry=fs.retry - released,
        backoff=fs.backoff,
        link_up=link_up,
    )
    view = FaultView(
        obs_row=obs_row,
        stale=stale,
        cloud_cap=cloud_cap,
        cloud_on=cloud_on,
        released=released,
        bw_scale=bw_scale,
        link_on=link_on,
    )
    return nxt, view


def requeue_failed(
    fs: FaultState,
    fp: FaultParams,
    w_eff: Array,
    key: Array,
) -> Tuple[FaultState, Array]:
    """Draws per-(type, cloud) task failures out of this slot's
    effective processing `w_eff [M, N]`, banks them in the retry pool,
    and moves the backoff level: up on any failure at the cloud, one
    step down on a clean slot (bounded by `backoff_max`). Returns
    (next state, failed [M, N])."""
    with phase("fault_retry"):
        failed = _stoch_round(w_eff * fp.task_p_fail[None, :], key)
        fail_n = jnp.sum(failed, axis=0)
        bmax = fp.backoff_max.astype(jnp.int32)
        backoff = jnp.where(
            fail_n > 0.0,
            jnp.minimum(fs.backoff + 1, bmax),
            jnp.maximum(fs.backoff - 1, 0),
        )
        return (
            fs._replace(retry=fs.retry + failed, backoff=backoff),
            failed,
        )
