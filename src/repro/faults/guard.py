"""Graceful degradation: the StalenessGuardPolicy wrapper.

Base policies in this repo are deliberately fault-blind -- they model
the fair-weather scheduler and ignore the `fault_view` kwarg the
faulted simulator passes. All degradation behavior lives here, in one
wrapper that works on any drift-plus-penalty policy (anything with a
`V` field: CarbonIntensityPolicy, LookaheadDPPPolicy,
NetworkAwareDPPPolicy):

  * staleness blending -- the effective penalty weight decays linearly
    with the carbon signal's age, V_eff = V * max(0, 1 - stale/s0).
    Past `stale_after` slots the policy is exactly the V=0
    drift-minimizer: dispatch on pure backpressure, process anything
    queued -- carbon-blind but throughput-stable, which is the right
    trade when the carbon numbers are fiction anyway;
  * outage-aware dispatch -- down clouds get `outage_penalty` added to
    their Qc columns before scoring, so the argmin target selection
    never points at them and dispatch stops entirely when everything is
    down (the penalized b turns positive). Processing is unaffected:
    the simulator already zeroes a down cloud's energy budget, so its
    fill takes nothing regardless of scores. Dead WAN routes get the
    same treatment through the Qt term when a link view is present.

With a fresh signal and no outage both adjustments are exact identities
(V * 1.0, Qc + 0.0), so the guard is bitwise-equivalent to its inner
policy under zero faults -- asserted in tests/test_faults.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StalenessGuardPolicy:
    """Wraps a DPP-family policy with staleness + outage degradation.

    `stale_after`: carbon-signal age (slots) at which the carbon
    penalty is fully distrusted (V_eff reaches 0).
    `outage_penalty`: virtual backlog added to unavailable clouds /
    routes; anything larger than any reachable queue length works.
    """

    inner: object
    stale_after: int = 8
    outage_penalty: float = 1e9

    def __post_init__(self):
        if self.stale_after <= 0:
            raise ValueError(
                f"stale_after={self.stale_after} must be positive "
                "(it divides the staleness counter)"
            )
        if not hasattr(self.inner, "V"):
            raise ValueError(
                "StalenessGuardPolicy needs a drift-plus-penalty inner "
                f"policy with a V field; got {type(self.inner).__name__}"
            )

    def __call__(
        self,
        state,
        spec,
        Ce: Array,
        Cc: Array,
        arrivals: Array,
        key: Array | None = None,
        *,
        fault_view=None,
        forecast: Array | None = None,
        graph=None,
        Qt: Array | None = None,
        deadline_view=None,
    ):
        inner = self.inner
        if fault_view is not None:
            s0 = jnp.asarray(float(self.stale_after), jnp.float32)
            decay = jnp.clip(
                1.0 - fault_view.stale.astype(jnp.float32) / s0, 0.0, 1.0
            )
            inner = dataclasses.replace(
                inner, V=jnp.asarray(inner.V, jnp.float32) * decay
            )
            big = jnp.asarray(self.outage_penalty, jnp.float32)
            state = state._replace(
                Qc=state.Qc + big * (1.0 - fault_view.cloud_on)[None, :]
            )
            if Qt is not None and fault_view.link_on is not None:
                Qt = Qt + big * (1.0 - fault_view.link_on)[None, :]
        kwargs = {}
        if forecast is not None:
            kwargs["forecast"] = forecast
        if deadline_view is not None:
            # Deadline urgency composes with staleness decay: the inner
            # deadline-aware policy escalates from the already-decayed
            # V_eff, so a stale signal AND a due task both push toward
            # pure backpressure rather than fighting each other.
            kwargs["deadline_view"] = deadline_view
        if graph is not None:
            return inner(
                state, spec, Ce, Cc, arrivals, key,
                graph=graph, Qt=Qt, **kwargs,
            )
        return inner(state, spec, Ce, Cc, arrivals, key, **kwargs)
