"""Faulted simulators: the core scan bodies with fault processes in
the carry.

`core.simulator.simulate(..., faults=...)` and
`network.sim.simulate_network(..., faults=...)` delegate here, so every
entry point (simulate_fleet lanes, forecaster threading, record modes)
picks the fault layer up by passing a FaultParams. With `faults=None`
the originals run their unchanged bodies -- and with
`faults=no_faults(...)` these bodies reduce to bitwise identities of
them (tests/test_faults.py asserts both, on both score backends).

Slot order (the fault hooks around the fault-free order):

  true carbon, arrivals
  -> fault chains step (outages/brownouts/flaps/telemetry), retry pool
     releases toward Qc with exponential backoff
  -> policy acts on the OBSERVED (possibly stale) intensities, a spec
     whose cloud budgets are scaled by the capacity factors, and a
     `fault_view=` kwarg (base policies ignore it; StalenessGuardPolicy
     degrades on it)
  -> service masking: w_eff = w * cloud_on -- a hard-down cloud
     processes nothing even if the policy scheduled it
  -> emissions at TRUE intensities on the effective action
  -> task failures drawn out of w_eff into the retry pool; their spent
     energy is already in the ledger and is reported as `wasted`
  -> queues step: Qc gains dispatches/deliveries + released retries.

Conservation (per slot, exact in float32 integral counts):
  cum(arrived) = Qe + Qc [+ Qt] + retry + cum(processed) - cum(failed)
-- the hypothesis property in tests/test_faults_properties.py.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.queueing import (
    Action,
    NetworkSpec,
    NetworkState,
    emissions,
    init_state,
)
from repro.core.simulator import _record_scan, init_forecaster_carry
from repro.faults.model import (
    FAULT_STREAM_SALT,
    FaultParams,
    init_faults,
    requeue_failed,
    step_faults,
)
from repro.telemetry.stream import split_telemetry
from repro.telemetry.taps import (
    TelemetryProbe,
    finalize_taps,
    init_taps,
    step_taps,
)

Array = jax.Array


class FaultSimResult(NamedTuple):
    """SimResult plus the fault ledger. `processed` counts processing
    attempts on up clouds; completed work is processed - failed.
    `backlog` is the post-step total (Qe + Qc + retry) every slot, so
    recovery analyses never need full queue recording."""

    emissions: Array      # [T] per-slot carbon (true intensities)
    cum_emissions: Array  # [T]
    Qe: Array             # [R, M] edge queues (post-step)
    Qc: Array             # [R, M, N] cloud queues (post-step)
    retry: Array          # [R, M, N] retry pool (post-step)
    arrived: Array        # [T] tasks arriving at the edge
    dispatched: Array     # [T] tasks dispatched
    processed: Array      # [T] processing attempts (post service mask)
    energy_edge: Array    # [T]
    energy_cloud: Array   # [T, N]
    failed: Array         # [T] tasks failed and banked for retry
    requeued: Array       # [T] retry tasks released back into Qc
    wasted: Array         # [T] carbon spent on failed attempts
    stale: Array          # [T] carbon-signal age seen by the policy
    clouds_down: Array    # [T] clouds with zero capacity this slot
    backlog: Array        # [T] Qe + Qc + retry totals (post-step)
    telemetry: object = None  # repro.telemetry.Telemetry frame, or None
    deadlines: object = None  # repro.deadlines.DeadlineLedger, or None

    @property
    def final_backlog(self) -> Array:
        return (
            self.Qe[-1].sum() + self.Qc[-1].sum() + self.retry[-1].sum()
        )


class NetFaultSimResult(NamedTuple):
    """NetSimResult plus the fault ledger (see FaultSimResult)."""

    emissions: Array
    cum_emissions: Array
    Qe: Array             # [R, M]
    Qc: Array             # [R, M, N]
    Qt: Array             # [R, M, L]
    retry: Array          # [R, M, N]
    arrived: Array        # [T]
    dispatched: Array     # [T]
    delivered: Array      # [T]
    processed: Array      # [T]
    energy_edge: Array    # [T]
    energy_transfer: Array  # [T]
    energy_cloud: Array   # [T, N]
    failed: Array         # [T]
    requeued: Array       # [T]
    wasted: Array         # [T]
    stale: Array          # [T]
    clouds_down: Array    # [T]
    links_down: Array     # [T] routes with zero bandwidth this slot
    backlog: Array        # [T] Qe + Qc + Qt + retry (post-step)
    telemetry: object = None  # repro.telemetry.Telemetry frame, or None
    deadlines: object = None  # repro.deadlines.DeadlineLedger, or None

    @property
    def final_backlog(self) -> Array:
        return (
            self.Qe[-1].sum() + self.Qc[-1].sum()
            + self.Qt[-1].sum() + self.retry[-1].sum()
        )


def simulate_faulted(
    policy: Callable,
    spec: NetworkSpec,
    faults: FaultParams,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
    state0: NetworkState | None = None,
    forecaster: Callable | None = None,
    error_params=None,
    record: str | int = "full",
    telemetry=None,
    stream_lane=None,
    deadlines=None,
) -> FaultSimResult:
    """The link-free faulted run; see the module docstring for slot
    order. The fault PRNG stream is `fold_in(key, FAULT_STREAM_SALT)`,
    leaving the carbon/arrival/policy streams bit-identical to the
    fault-free simulator's.

    `deadlines` composes the deadline layer with the fault layer: the
    deadline clock runs on edge waiting, so outages that starve
    dispatch show up as expiries (or, with shedding on, as admission
    rejections) -- retry-pool tasks are already dispatched and never
    expire. The deadline layer adds no PRNG stream, so the
    no_deadlines run stays bitwise-identical to `deadlines=None`.
    """
    telemetry, stream = split_telemetry(telemetry)
    pe, pc, Pe, Pc = spec.as_arrays()
    if state0 is None:
        state0 = init_state(spec.M, spec.N)
    if deadlines is not None:
        from repro.deadlines.model import (
            DeadlineLedger,
            deadline_view,
            init_deadlines,
            step_deadlines,
        )
    k_carbon, k_arrive, k_policy = jax.random.split(key, 3)
    k_fault = jax.random.fold_in(key, FAULT_STREAM_SALT)
    fs0 = init_faults(spec.M, spec.N)

    if forecaster is not None:
        fcarry0 = init_forecaster_carry(
            forecaster, spec.N, k_carbon, carbon_source, error_params
        )

    def body(carry, t):
        state, fs, fcarry, tap, dstate = carry
        Ce, Cc = carbon_source(t, k_carbon)
        a = arrival_source(t, k_arrive)
        k_t = jax.random.fold_in(k_policy, t)
        k_step, k_fail = jax.random.split(jax.random.fold_in(k_fault, t))

        fs, view = step_faults(
            fs, faults, t, k_step, jnp.concatenate([Ce[None], Cc])
        )
        spec_t = NetworkSpec(pe=pe, pc=pc, Pe=Pe, Pc=Pc * view.cloud_cap)
        obs_Ce, obs_Cc = view.obs_row[0], view.obs_row[1:]
        pkw = {}
        if deadlines is not None:
            pkw["deadline_view"] = deadline_view(deadlines, dstate)
        if forecaster is None:
            act: Action = policy(
                state, spec_t, obs_Ce, obs_Cc, a, k_t, fault_view=view,
                **pkw,
            )
        else:
            # The forecaster sees what the telemetry feed delivers: the
            # frozen row during dropouts (clairvoyant table forecasters
            # read their table directly and stay oracle by design).
            fcarry = forecaster.update(fcarry, view.obs_row)
            act = policy(
                state, spec_t, obs_Ce, obs_Cc, a, k_t, fault_view=view,
                forecast=forecaster.predict(fcarry, t), **pkw,
            )
        w_eff = act.w * view.cloud_on[None, :]
        act_eff = Action(d=act.d, w=w_eff)
        C_t = emissions(spec, act_eff, Ce, Cc)
        fs, failed = requeue_failed(fs, faults, w_eff, k_fail)
        d_sum = jnp.sum(act.d, axis=1)
        if deadlines is None:
            arr_term = a
            missed = shed = jnp.float32(0.0)
        else:
            dstate, admitted, expired, shed_v = step_deadlines(
                deadlines, dstate, d_sum, a
            )
            arr_term = admitted - expired
            missed = jnp.sum(expired)
            shed = jnp.sum(shed_v)
        nxt = NetworkState(
            Qe=jnp.maximum(state.Qe - d_sum, 0.0) + arr_term,
            Qc=jnp.maximum(state.Qc - w_eff, 0.0)
            + act.d + view.released,
        )
        backlog = (
            jnp.sum(nxt.Qe) + jnp.sum(nxt.Qc) + jnp.sum(fs.retry)
        )
        wasted = jnp.sum(Cc * jnp.sum(failed * pc, axis=0))
        out = (
            C_t,
            jnp.sum(a),
            jnp.sum(act.d),
            jnp.sum(w_eff),
            jnp.sum(act.d * pe[:, None]),
            jnp.sum(w_eff * pc, axis=0),
            jnp.sum(failed),
            jnp.sum(view.released),
            wasted,
            view.stale.astype(jnp.float32),
            jnp.sum(1.0 - view.cloud_on),
            backlog,
        )
        if deadlines is not None:
            out = out + (missed, shed, jnp.sum(admitted))
        if telemetry is None:
            return (nxt, fs, fcarry, tap, dstate), out
        probe = TelemetryProbe(
            emissions=C_t,
            arrived=jnp.sum(a),
            dispatched=jnp.sum(act.d, axis=0),
            processed=jnp.sum(w_eff),
            failed=jnp.sum(failed),
            wasted=wasted,
            backlog=backlog,
            stale=view.stale,
            clouds_down=jnp.sum(1.0 - view.cloud_on),
            retry_depth=jnp.sum(fs.retry),
            transfer_occupancy=jnp.float32(0.0),
            missed=missed,
            shed=shed,
        )
        tap, tseries = step_taps(telemetry, tap, probe)
        return (nxt, fs, fcarry, tap, dstate), (out, tseries)

    carry0 = (
        state0, fs0,
        fcarry0 if forecaster is not None else (),
        init_taps() if telemetry is not None else (),
        init_deadlines(spec.M, deadlines.rings.shape[-1])
        if deadlines is not None else (),
    )
    if deadlines is None:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[1].retry
        )
    else:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[1].retry, carry[4].Qd
        )
    scalars, states = _record_scan(
        body, state_of,
        carry0, T, record, stream=stream, lane=stream_lane,
    )
    if telemetry is None:
        tel = None
    else:
        scalars, tseries = scalars
        tel = finalize_taps(telemetry, tseries)
    if deadlines is None:
        (C, arr, disp, proc, ee, ec,
         fail, req, waste, stale, down, backlog) = scalars
        (Qe, Qc, retry), led = states, None
    else:
        (C, arr, disp, proc, ee, ec, fail, req, waste, stale, down,
         backlog, missed, shed, adm) = scalars
        Qe, Qc, retry, Qd = states
        led = DeadlineLedger(missed=missed, shed=shed, admitted=adm,
                             Qd=Qd)
    return FaultSimResult(
        emissions=C, cum_emissions=jnp.cumsum(C),
        Qe=Qe, Qc=Qc, retry=retry,
        arrived=arr, dispatched=disp, processed=proc,
        energy_edge=ee, energy_cloud=ec,
        failed=fail, requeued=req, wasted=waste,
        stale=stale, clouds_down=down, backlog=backlog,
        telemetry=tel, deadlines=led,
    )


def simulate_network_faulted(
    policy: Callable,
    spec: NetworkSpec,
    graph,
    faults: FaultParams,
    carbon_source: Callable,
    arrival_source: Callable,
    T: int,
    key: Array,
    state0: NetworkState | None = None,
    forecaster: Callable | None = None,
    error_params=None,
    record: str | int = "full",
    telemetry=None,
    stream_lane=None,
    deadlines=None,
) -> NetFaultSimResult:
    """The WAN faulted run: link flaps scale each route's bandwidth in
    `step_links`; everything else mirrors `simulate_faulted`
    (including the `deadlines=` layer, whose clock here runs on edge
    waiting before link injection)."""
    telemetry, stream = split_telemetry(telemetry)
    if deadlines is not None:
        from repro.deadlines.model import (
            DeadlineLedger,
            deadline_view,
            init_deadlines,
            step_deadlines,
        )
    from repro.network.transfer import (
        NetAction,
        init_links,
        land_in_clouds,
        network_emissions,
        step_links,
        transfer_energy,
    )

    pe, pc, Pe, Pc = spec.as_arrays()
    if state0 is None:
        state0 = init_state(spec.M, spec.N)
    ls0 = init_links(spec.M, graph.L)
    k_carbon, k_arrive, k_policy = jax.random.split(key, 3)
    k_fault = jax.random.fold_in(key, FAULT_STREAM_SALT)
    fs0 = init_faults(spec.M, spec.N, graph.L)
    if faults.link_p_down is None:
        raise ValueError(
            "network fault runs need link fields: build the FaultParams "
            f"with L={graph.L} (make_faults(N, L=...)) so the flap chain "
            "matches the graph"
        )

    if forecaster is not None:
        fcarry0 = init_forecaster_carry(
            forecaster, spec.N, k_carbon, carbon_source, error_params
        )

    def body(carry, t):
        state, ls, fs, fcarry, tap, dstate = carry
        Ce, Cc = carbon_source(t, k_carbon)
        a = arrival_source(t, k_arrive)
        k_t = jax.random.fold_in(k_policy, t)
        k_step, k_fail = jax.random.split(jax.random.fold_in(k_fault, t))

        fs, view = step_faults(
            fs, faults, t, k_step, jnp.concatenate([Ce[None], Cc])
        )
        spec_t = NetworkSpec(pe=pe, pc=pc, Pe=Pe, Pc=Pc * view.cloud_cap)
        obs_Ce, obs_Cc = view.obs_row[0], view.obs_row[1:]
        pkw = {}
        if deadlines is not None:
            pkw["deadline_view"] = deadline_view(deadlines, dstate)
        if forecaster is None:
            act: NetAction = policy(
                state, spec_t, obs_Ce, obs_Cc, a, k_t,
                graph=graph, Qt=ls.Qt, fault_view=view, **pkw,
            )
        else:
            fcarry = forecaster.update(fcarry, view.obs_row)
            act = policy(
                state, spec_t, obs_Ce, obs_Cc, a, k_t,
                graph=graph, Qt=ls.Qt, fault_view=view,
                forecast=forecaster.predict(fcarry, t), **pkw,
            )
        w_eff = act.w * view.cloud_on[None, :]
        act_eff = NetAction(dt=act.dt, w=w_eff)
        C_t = network_emissions(spec, graph, act_eff, Ce, Cc)
        ls_next, delivered = step_links(
            ls, graph, act.dt, bw_scale=view.bw_scale
        )
        land = land_in_clouds(delivered, graph, spec.N)
        fs, failed = requeue_failed(fs, faults, w_eff, k_fail)
        d_sum = jnp.sum(act.dt, axis=1)
        if deadlines is None:
            arr_term = a
            missed = shed = jnp.float32(0.0)
        else:
            dstate, admitted, expired, shed_v = step_deadlines(
                deadlines, dstate, d_sum, a
            )
            arr_term = admitted - expired
            missed = jnp.sum(expired)
            shed = jnp.sum(shed_v)
        nxt = NetworkState(
            Qe=jnp.maximum(state.Qe - d_sum, 0.0) + arr_term,
            Qc=jnp.maximum(state.Qc - w_eff, 0.0)
            + land + view.released,
        )
        backlog = (
            jnp.sum(nxt.Qe) + jnp.sum(nxt.Qc)
            + jnp.sum(ls_next.Qt) + jnp.sum(fs.retry)
        )
        wasted = jnp.sum(Cc * jnp.sum(failed * pc, axis=0))
        out = (
            C_t,
            jnp.sum(a),
            jnp.sum(act.dt),
            jnp.sum(delivered),
            jnp.sum(w_eff),
            jnp.sum(act.dt * pe[:, None]),
            jnp.sum(transfer_energy(graph, act.dt)),
            jnp.sum(w_eff * pc, axis=0),
            jnp.sum(failed),
            jnp.sum(view.released),
            wasted,
            view.stale.astype(jnp.float32),
            jnp.sum(1.0 - view.cloud_on),
            jnp.sum(1.0 - view.link_on),
            backlog,
        )
        if deadlines is not None:
            out = out + (missed, shed, jnp.sum(admitted))
        if telemetry is None:
            return (nxt, ls_next, fs, fcarry, tap, dstate), out
        probe = TelemetryProbe(
            emissions=C_t,
            arrived=jnp.sum(a),
            dispatched=jnp.sum(land, axis=0),
            processed=jnp.sum(w_eff),
            failed=jnp.sum(failed),
            wasted=wasted,
            backlog=backlog,
            stale=view.stale,
            clouds_down=jnp.sum(1.0 - view.cloud_on),
            retry_depth=jnp.sum(fs.retry),
            transfer_occupancy=jnp.sum(ls_next.Qt),
            missed=missed,
            shed=shed,
        )
        tap, tseries = step_taps(telemetry, tap, probe)
        return (nxt, ls_next, fs, fcarry, tap, dstate), (out, tseries)

    carry0 = (
        state0, ls0, fs0,
        fcarry0 if forecaster is not None else (),
        init_taps() if telemetry is not None else (),
        init_deadlines(spec.M, deadlines.rings.shape[-1])
        if deadlines is not None else (),
    )
    if deadlines is None:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[1].Qt, carry[2].retry
        )
    else:
        state_of = lambda carry: (  # noqa: E731
            carry[0].Qe, carry[0].Qc, carry[1].Qt, carry[2].retry,
            carry[5].Qd,
        )
    scalars, states = _record_scan(
        body, state_of,
        carry0, T, record, stream=stream, lane=stream_lane,
    )
    if telemetry is None:
        tel = None
    else:
        scalars, tseries = scalars
        tel = finalize_taps(telemetry, tseries)
    if deadlines is None:
        (C, arr, disp, deliv, proc, ee, et, ec,
         fail, req, waste, stale, cdown, ldown, backlog) = scalars
        (Qe, Qc, Qt, retry), led = states, None
    else:
        (C, arr, disp, deliv, proc, ee, et, ec, fail, req, waste,
         stale, cdown, ldown, backlog, missed, shed, adm) = scalars
        Qe, Qc, Qt, retry, Qd = states
        led = DeadlineLedger(missed=missed, shed=shed, admitted=adm,
                             Qd=Qd)
    return NetFaultSimResult(
        emissions=C, cum_emissions=jnp.cumsum(C),
        Qe=Qe, Qc=Qc, Qt=Qt, retry=retry,
        arrived=arr, dispatched=disp, delivered=deliv, processed=proc,
        energy_edge=ee, energy_transfer=et, energy_cloud=ec,
        failed=fail, requeued=req, wasted=waste,
        stale=stale, clouds_down=cdown, links_down=ldown,
        backlog=backlog,
        telemetry=tel, deadlines=led,
    )
