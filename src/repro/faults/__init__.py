"""Fault injection + graceful degradation for the scheduler.

`model.py` -- the fault processes (Markov outages, brownouts, link
flaps, telemetry dropouts, task failure + backoff retry) as
scan-carried pure-JAX state; `sim.py` -- the faulted simulator bodies
that `simulate(..., faults=...)` delegates to; `guard.py` -- the
StalenessGuardPolicy degradation wrapper. The zero-fault anchor
(`no_faults` => bitwise-identical trajectories to the fault-free
simulator) is this subsystem's regression invariant.
"""
from repro.faults.guard import StalenessGuardPolicy
from repro.faults.model import (
    FaultParams,
    FaultState,
    FaultView,
    init_faults,
    make_faults,
    no_faults,
    requeue_failed,
    stack_faults,
    step_faults,
)
from repro.faults.sim import (
    FaultSimResult,
    NetFaultSimResult,
    simulate_faulted,
    simulate_network_faulted,
)

__all__ = [
    "FaultParams",
    "FaultState",
    "FaultView",
    "FaultSimResult",
    "NetFaultSimResult",
    "StalenessGuardPolicy",
    "init_faults",
    "make_faults",
    "no_faults",
    "requeue_failed",
    "simulate_faulted",
    "simulate_network_faulted",
    "stack_faults",
    "step_faults",
]
