"""Mixture-of-Experts layer.

Two execution paths with identical routing semantics:

* `moe_dense`     -- every expert computed for every token, outputs masked
                     by the top-k router weights. Exact; O(E/topk) FLOP
                     overhead. Used for smoke tests and as the oracle in
                     property tests.
* `moe_capacity`  -- production path: capacity-factor token dispatch into
                     per-expert buffers (scatter), expert matmuls, combine.
                     Tokens over capacity are dropped (standard TPU MoE).
                     Under the production mesh the expert axis is sharded
                     ('model' = EP) and XLA lowers dispatch/combine into
                     all-to-alls; see distributed/sharding.py.

Routing: softmax router (fp32), top-k, renormalized weights; optional
shared experts (always on) and a dense residual branch (arctic) are
handled in transformer.py, not here.

Expert-count padding: configs whose n_experts doesn't divide the EP axis
are padded with dummy experts whose router logits are -inf (never
selected); `n_experts_padded` reports the padded count.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.api import shard_hint
from repro.models.layers import dense_init, dtype_of

Array = jax.Array


def padded_expert_count(n_experts: int, ep: int = 16) -> int:
    return int(math.ceil(n_experts / ep) * ep)


def init_moe(key, cfg, dtype, ep: int | None = None):
    d, k = cfg.d_model, cfg.n_experts_active
    ff = cfg.moe_d_ff or cfg.d_ff
    E = padded_expert_count(cfg.n_experts, ep or cfg.ep_axis)
    ks = jax.random.split(key, 4)
    gated = cfg.activation in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02, dtype=jnp.float32),
        "w_in": dense_init(ks[1], (E, d, ff), dtype=dtype),
        "w_out": dense_init(
            ks[2], (E, ff, d), scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers),
            dtype=dtype,
        ),
    }
    if gated:
        p["w_gate"] = dense_init(ks[3], (E, d, ff), dtype=dtype)
    return p


def _route(p, x: Array, cfg) -> Tuple[Array, Array]:
    """Returns (weights [T,k], idx [T,k]); pads masked to -inf."""
    E = p["router"].shape[1]
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    if E > cfg.n_experts:  # mask padded experts out of routing
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.n_experts_active)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )
    return weights, idx


def _expert_ffn(p, h: Array, cfg, cd) -> Array:
    """h: [..., E, C, D] blocked per expert -> expert MLP."""
    up = jnp.einsum("ecd,edf->ecf", h, p["w_in"].astype(cd))
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(cd))
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        up = act * up
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, p["w_out"].astype(cd))


def moe_dense(p, x: Array, cfg) -> Array:
    """Exact MoE: all experts on all tokens (tiny configs only)."""
    B, S, D = x.shape
    cd = dtype_of(cfg.compute_dtype)
    T = B * S
    xt = x.reshape(T, D)
    weights, idx = _route(p, xt, cfg)
    E = p["router"].shape[1]
    up = jnp.einsum("td,edf->tef", xt, p["w_in"].astype(cd))
    if "w_gate" in p:
        g = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(cd))
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        up = act * up
    else:
        up = jax.nn.gelu(up)
    y_all = jnp.einsum("tef,efd->ted", up, p["w_out"].astype(cd))  # [T,E,D]
    gate = jnp.zeros((T, E), jnp.float32)
    gate = jax.vmap(lambda g_row, i, w: g_row.at[i].add(w))(gate, idx, weights)
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), gate)
    return y.reshape(B, S, D).astype(x.dtype)


def moe_capacity(
    p, x: Array, cfg, *, capacity_factor: float = 1.25
) -> Array:
    """Capacity-based dispatch/combine (production path).

    [B,S,D] -> flatten T tokens -> top-k route -> position-in-expert via
    cumsum -> scatter into [E, C, D] -> expert FFN -> gather back.
    Token (t, slot j) beyond expert capacity C is dropped (weight stays,
    renormalization keeps output scale).
    """
    B, S, D = x.shape
    cd = dtype_of(cfg.compute_dtype)
    T = B * S
    k = cfg.n_experts_active
    E = p["router"].shape[1]
    C = int(max(1, math.ceil(T * k * capacity_factor / E)))

    xt = x.reshape(T, D)
    weights, idx = _route(p, xt, cfg)  # [T,k]

    flat_e = idx.reshape(-1)  # [T*k] expert of each (token, slot)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    # scatter tokens into expert buffers
    buf = jnp.zeros((E, C, D), cd)
    tok_of = jnp.repeat(jnp.arange(T), k)
    e_idx = jnp.where(keep, flat_e, 0)
    c_idx = jnp.where(keep, pos, 0)
    vals = jnp.where(keep[:, None], xt[tok_of].astype(cd), 0)
    buf = buf.at[e_idx, c_idx].add(vals, mode="drop")
    buf = shard_hint(buf, "moe_buf")

    out_buf = _expert_ffn(p, buf, cfg, cd)  # [E, C, D]
    out_buf = shard_hint(out_buf, "moe_buf")

    # combine: gather each (token, slot)'s output, weight, sum over k
    gathered = out_buf[e_idx, c_idx]  # [T*k, D]
    w_flat = weights.reshape(-1) * keep.astype(jnp.float32)
    y = jnp.zeros((T, D), jnp.float32)
    y = y.at[tok_of].add(gathered.astype(jnp.float32) * w_flat[:, None])
    return y.reshape(B, S, D).astype(x.dtype)


def moe_ep_shardmap(
    p, x: Array, cfg, mesh, dp_axes, ep_axis: str,
    *, capacity_factor: float = 1.25,
) -> Array:
    """Expert-parallel MoE via shard_map (GShard-style, TPU-native).

    Tokens are sharded over (dp x ep): each device routes its local
    tokens, scatters them into per-expert buffers, exchanges expert shards
    with one all_to_all over the 'model' axis, runs its local experts
    (weights FSDP-gathered over 'data' just-in-time), and reverses the
    exchange. Token count per device is T/(dp*ep); the dispatch tensors
    never exceed [E, C_loc, D] with C_loc = ceil(T_loc*k*cf/E).

    Falls back to `moe_capacity` shapes when the sequence doesn't divide
    the ep axis (e.g. decode steps with S=1) -- see apply_moe.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compat import shard_map

    B, S, D = x.shape
    E = p["router"].shape[1]
    k = cfg.n_experts_active
    ep = mesh.shape[ep_axis]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    cd = dtype_of(cfg.compute_dtype)
    T_loc = (B // dp) * (S // ep)
    C_loc = int(max(1, math.ceil(T_loc * k * capacity_factor / E)))
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    gated = "w_gate" in p

    def block(router, w_in, w_gate, w_out, xb):
        # router [D,E] replicated; w_in [E/ep, D/dp, F]; xb [B/dp, S/ep, D]
        b_loc, s_loc, _ = xb.shape
        xt = xb.reshape(T_loc, D)
        logits = jnp.einsum(
            "td,de->te", xt.astype(jnp.float32), router.astype(jnp.float32)
        )
        if E > cfg.n_experts:
            pad_mask = jnp.arange(E) >= cfg.n_experts
            logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, idx = jax.lax.top_k(probs, k)
        weights = weights / jnp.maximum(
            jnp.sum(weights, -1, keepdims=True), 1e-9
        )

        flat_e = idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, flat_e[:, None], axis=1
        )[:, 0]
        keep = pos < C_loc
        tok_of = jnp.repeat(jnp.arange(T_loc), k)
        e_idx = jnp.where(keep, flat_e, 0)
        c_idx = jnp.where(keep, pos, 0)
        vals = jnp.where(keep[:, None], xt[tok_of].astype(cd), 0)
        buf = jnp.zeros((E, C_loc, D), cd).at[e_idx, c_idx].add(
            vals, mode="drop"
        )

        # exchange expert shards: [E, C_loc, D] -> [E/ep, ep*C_loc, D]
        buf = jax.lax.all_to_all(
            buf, ep_axis, split_axis=0, concat_axis=1, tiled=True
        )

        # FSDP-gather local experts' weights over 'data'
        w_in_full = jax.lax.all_gather(
            w_in, dp_axes, axis=1, tiled=True
        ) if dp_axes else w_in
        w_out_full = jax.lax.all_gather(
            w_out, dp_axes, axis=2, tiled=True
        ) if dp_axes else w_out
        up = jnp.einsum("ecd,edf->ecf", buf, w_in_full.astype(cd))
        if gated:
            w_g_full = jax.lax.all_gather(
                w_gate, dp_axes, axis=1, tiled=True
            ) if dp_axes else w_gate
            g = jnp.einsum("ecd,edf->ecf", buf, w_g_full.astype(cd))
            act = jax.nn.silu(g) if cfg.activation == "swiglu" else \
                jax.nn.gelu(g)
            up = act * up
        else:
            up = jax.nn.gelu(up)
        out_buf = jnp.einsum("ecf,efd->ecd", up, w_out_full.astype(cd))

        # reverse exchange and combine locally
        out_buf = jax.lax.all_to_all(
            out_buf, ep_axis, split_axis=1, concat_axis=0, tiled=True
        )
        gathered = out_buf[e_idx, c_idx]
        w_flat = weights.reshape(-1) * keep.astype(jnp.float32)
        y = jnp.zeros((T_loc, D), jnp.float32).at[tok_of].add(
            gathered.astype(jnp.float32) * w_flat[:, None]
        )
        return y.reshape(b_loc, s_loc, D).astype(xb.dtype)

    w_gate_arg = p.get("w_gate", p["w_in"])  # placeholder when ungated
    fn = shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(None, None),                      # router replicated
            P(ep_axis, dp_spec, None),          # w_in  [E, D, F]
            P(ep_axis, dp_spec, None),          # w_gate
            P(ep_axis, None, dp_spec),          # w_out [E, F, D]
            P(dp_spec, ep_axis, None),          # x tokens over dp x ep
        ),
        out_specs=P(dp_spec, ep_axis, None),
        check_vma=False,
    )
    return fn(p["router"], p["w_in"], w_gate_arg, p["w_out"], x)


def apply_moe(p, x: Array, cfg) -> Array:
    if cfg.moe_path == "dense":
        return moe_dense(p, x, cfg)
    ctx = None
    try:
        from repro.distributed.api import mesh_context
        ctx = mesh_context()
    except Exception:
        ctx = None
    if ctx is not None:
        mesh, dp_axes, ep_axis = ctx
        ep = mesh.shape[ep_axis]
        dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
        B, S = x.shape[0], x.shape[1]
        if B % max(dp, 1) == 0 and S % ep == 0:
            return moe_ep_shardmap(
                p, x, cfg, mesh, dp_axes, ep_axis,
                capacity_factor=cfg.moe_capacity_factor,
            )
    return moe_capacity(p, x, cfg, capacity_factor=cfg.moe_capacity_factor)
