"""Unified Model API + ShapeDtypeStruct input specs for every dry-run cell.

`Model(cfg)` exposes:
  init(key) -> params
  loss(params, batch) -> (scalar, metrics)       [train shapes]
  prefill(params, batch) -> (logits, cache)      [prefill shapes]
  decode_step(params, token, cache) -> (logits, cache)  [decode shapes]
  input_specs(shape_name) -> pytree of jax.ShapeDtypeStruct
  cache_specs(seq_len, batch) -> cache pytree spec       [decode shapes]

Frontend stubs per the brief: VLM patches and audio frames are provided
as precomputed embeddings in input_specs (the modality encoder is out of
scope; the backbone is what the cells exercise).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, ModelConfig
from repro.models import layers as L
from repro.models import serving, transformer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters ----
    def init(self, key) -> Dict[str, Any]:
        return transformer.init_params(key, self.cfg)

    def param_specs(self) -> Dict[str, Any]:
        """Shapes without allocation (for dry-run lowering)."""
        return jax.eval_shape(lambda k: self.init(k), jax.random.PRNGKey(0))

    # ---- entry points ----
    def loss(self, params, batch):
        return transformer.lm_loss(params, batch, self.cfg)

    def prefill(self, params, batch, cache_len=None):
        return serving.prefill(params, batch, self.cfg, cache_len)

    def decode_step(self, params, token, cache):
        return serving.decode_step(params, token, cache, self.cfg)

    # ---- specs ----
    def _emb_dtype(self):
        return L.dtype_of(self.cfg.compute_dtype)

    def train_specs(self, seq_len: int, batch: int) -> Dict[str, Any]:
        cfg = self.cfg
        i32 = jnp.int32
        if cfg.is_encoder_decoder:
            return {
                "frames": jax.ShapeDtypeStruct(
                    (batch, cfg.source_len, cfg.d_model), self._emb_dtype()
                ),
                "tokens": jax.ShapeDtypeStruct((batch, seq_len), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
            }
        if cfg.family == "vlm":
            s_text = seq_len - cfg.prefix_len
            return {
                "patches": jax.ShapeDtypeStruct(
                    (batch, cfg.prefix_len, cfg.d_model), self._emb_dtype()
                ),
                "tokens": jax.ShapeDtypeStruct((batch, s_text), i32),
                "labels": jax.ShapeDtypeStruct((batch, s_text), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq_len), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
        }

    def prefill_specs(self, seq_len: int, batch: int) -> Dict[str, Any]:
        spec = self.train_specs(seq_len, batch)
        spec.pop("labels", None)
        if self.cfg.is_encoder_decoder:
            # prefill = encode source + init decoder caches; no tokens yet
            spec.pop("tokens", None)
            spec["frames"] = jax.ShapeDtypeStruct(
                (batch, seq_len, self.cfg.d_model), self._emb_dtype()
            )
        return spec

    def cache_specs(self, seq_len: int, batch: int) -> Dict[str, Any]:
        cfg = self.cfg
        cd = self._emb_dtype()
        K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        i32 = jnp.int32

        def kv(n_layers, length):
            return jax.ShapeDtypeStruct((n_layers, batch, length, K, hd), cd)

        if cfg.family == "ssm":
            return {
                "ssm": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                     cfg.ssm_head_dim), jnp.float32,
                ),
                "conv": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, cfg.ssm_conv - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), cd,
                ),
            }
        if cfg.family == "hybrid":
            nb = cfg.n_layers // cfg.attn_every
            ni = cfg.attn_every - 1
            return {
                "k": kv(nb, seq_len),
                "v": kv(nb, seq_len),
                "ssm": jax.ShapeDtypeStruct(
                    (nb, ni, batch, cfg.ssm_heads, cfg.ssm_state,
                     cfg.ssm_head_dim), jnp.float32,
                ),
                "conv": jax.ShapeDtypeStruct(
                    (nb, ni, batch, cfg.ssm_conv - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), cd,
                ),
                "pos": jax.ShapeDtypeStruct((), i32),
            }
        if cfg.is_encoder_decoder:
            return {
                "k": kv(cfg.n_layers, seq_len),
                "v": kv(cfg.n_layers, seq_len),
                "ck": kv(cfg.n_layers, cfg.source_len),
                "cv": kv(cfg.n_layers, cfg.source_len),
                "pos": jax.ShapeDtypeStruct((), i32),
            }
        return {
            "k": kv(cfg.n_layers, seq_len),
            "v": kv(cfg.n_layers, seq_len),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    def decode_specs(self, seq_len: int, batch: int) -> Dict[str, Any]:
        return {
            "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "cache": self.cache_specs(seq_len, batch),
        }

    def input_specs(self, shape_name: str) -> Dict[str, Any]:
        s = SHAPES[shape_name]
        if s["kind"] == "train":
            return self.train_specs(s["seq_len"], s["global_batch"])
        if s["kind"] == "prefill":
            return self.prefill_specs(s["seq_len"], s["global_batch"])
        return self.decode_specs(s["seq_len"], s["global_batch"])

    # ---- concrete tiny batch (smoke tests) ----
    def dummy_batch(self, key, seq_len: int, batch: int) -> Dict[str, Array]:
        spec = self.train_specs(seq_len, batch)
        out = {}
        for name, sd in spec.items():
            k = jax.random.fold_in(key, hash(name) % (2**31))
            if sd.dtype == jnp.int32:
                out[name] = jax.random.randint(
                    k, sd.shape, 0, self.cfg.vocab_size
                )
            else:
                out[name] = jax.random.normal(k, sd.shape, sd.dtype)
        return out

    def init_cache(self, batch: int, seq_len: int) -> Dict[str, Array]:
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.cache_specs(seq_len, batch),
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
