"""Serving entry points: prefill (build caches) and single-token decode.

Cache pytrees per family (C = cache capacity = the cell's seq_len):
  dense/moe/vlm : {k [L,B,C,K,hd], v [...], pos ()}
  ssm           : {ssm [L,B,H,N,P], conv [L,B,W-1,ch]}
  hybrid        : {k [nb,B,C,K,hd], v, ssm [nb,ni,B,H,N,P],
                   conv [nb,ni,B,W-1,ch], pos ()}
  audio(encdec) : {k,v self [L,B,C,K,hd], ck,cv cross [L,B,Ssrc,K,hd], pos ()}

decode_step(params, token [B,1], cache) -> (logits [B,V], cache') is the
`serve_step` lowered by the decode_32k / long_500k dry-run cells.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2
from repro.models.transformer import (
    _apply_ffn,
    _unembed_weight,
    encoder_forward,
)

Array = jax.Array


def _ffn_sub(lp):
    return {k: lp[k] for k in ("mlp", "moe", "shared", "dense_res") if k in lp}


def _logits(params, x_last: Array, cfg) -> Array:
    w = _unembed_weight(params, cfg)
    return jnp.einsum(
        "bd,dv->bv", x_last.astype(jnp.float32), w.astype(jnp.float32)
    )


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def _attn_with_kv(lp, h, cfg, mask_mode, prefix_len):
    """Attention that also returns the K/V it computed (for cache build)."""
    cd = L.dtype_of(cfg.compute_dtype)
    B, S, _ = h.shape
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"].astype(cd))
    if "bk" in lp["attn"]:
        k = k + lp["attn"]["bk"].astype(cd)
        v = v + lp["attn"]["bv"].astype(cd)
    if cfg.rope_fraction > 0 and cfg.n_heads:
        cos, sin = L.rope_angles(
            jnp.arange(S), int(hd * cfg.rope_fraction), cfg.rope_theta
        )
        k = L.apply_rope(k, cos, sin, cfg.rope_fraction)
    y = L.gqa_attention(
        lp["attn"], h, cfg, mask_mode=mask_mode, prefix_len=prefix_len,
        kv_override=None,
    )
    # NOTE: gqa_attention recomputes k/v internally; XLA CSEs the duplicate
    # einsums away (verified in the lowered HLO), keeping this code simple.
    # The cache stores the ROTATED keys (decode_attention only rotates the
    # incoming key at `pos`), so rotation is applied before returning.
    return y, (k, v)


def prefill(params, batch: Dict[str, Array], cfg, cache_len: int | None = None
            ) -> Tuple[Array, Dict[str, Any]]:
    cd = L.dtype_of(cfg.compute_dtype)
    if cfg.family == "ssm":
        return _prefill_ssm(params, batch, cfg)
    if cfg.family == "hybrid":
        return _prefill_hybrid(params, batch, cfg, cache_len)
    if cfg.is_encoder_decoder:
        return _prefill_encdec(params, batch, cfg, cache_len)

    if cfg.family == "vlm":
        tok_emb = params["embed"].astype(cd)[batch["tokens"]]
        x = jnp.concatenate([batch["patches"].astype(cd), tok_emb], axis=1)
        mask_mode, prefix_len = "prefix", cfg.prefix_len
    else:
        x = params["embed"].astype(cd)[batch["tokens"]]
        mask_mode, prefix_len = "causal", 0
    B, S, _ = x.shape
    C = cache_len or S

    def block(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (k, v) = _attn_with_kv(lp, h, cfg, mask_mode, prefix_len)
        x = x + y
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(_ffn_sub(lp), h, cfg)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(block, x, params["layers"],
                               unroll=cfg.unroll_scans or 1)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    pad = C - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    return _logits(params, x[:, -1], cfg), cache


def _prefill_ssm(params, batch, cfg):
    cd = L.dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(cd)[batch["tokens"]]

    def block(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (hT, convT) = mamba2.mamba_forward(
            lp["mamba"], h, cfg, return_state=True
        )
        return x + y, (hT, convT)

    x, (ssm, conv) = jax.lax.scan(block, x, params["layers"],
                                  unroll=cfg.unroll_scans or 1)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    cache = {"ssm": ssm, "conv": conv}
    return _logits(params, x[:, -1], cfg), cache


def _prefill_hybrid(params, batch, cfg, cache_len):
    cd = L.dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(cd)[batch["tokens"]]
    B, S, _ = x.shape
    C = cache_len or S
    n_inner = cfg.attn_every - 1

    def block(x, bp):
        lp = bp["attn_layer"]
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (k, v) = _attn_with_kv(lp, h, cfg, "causal", 0)
        x = x + y
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(_ffn_sub(lp), h, cfg)
        ssms, convs = [], []
        for i in range(1, n_inner + 1):
            mlp_i = bp["mamba_layers"][f"m{i}"]
            h = L.apply_norm(mlp_i["ln1"], x, cfg.norm)
            y, (hT, convT) = mamba2.mamba_forward(
                mlp_i["mamba"], h, cfg, return_state=True
            )
            x = x + y
            h = L.apply_norm(mlp_i["ln2"], x, cfg.norm)
            x = x + _apply_ffn(_ffn_sub(mlp_i), h, cfg)
            ssms.append(hT)
            convs.append(convT)
        return x, (k, v, jnp.stack(ssms), jnp.stack(convs))

    x, (ks, vs, ssm, conv) = jax.lax.scan(block, x, params["layers"],
                                          unroll=cfg.unroll_scans or 1)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    pad = C - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": ks, "v": vs, "ssm": ssm, "conv": conv,
        "pos": jnp.asarray(S, jnp.int32),
    }
    return _logits(params, x[:, -1], cfg), cache


def _prefill_encdec(params, batch, cfg, cache_len):
    cd = L.dtype_of(cfg.compute_dtype)
    enc = encoder_forward(params, batch["frames"].astype(cd), cfg)
    B = enc.shape[0]
    C = cache_len or cfg.source_len
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def cross_kv(_, cp):
        ck = jnp.einsum("bsd,dhk->bshk", enc, cp["attn"]["wk"].astype(cd))
        cv = jnp.einsum("bsd,dhk->bshk", enc, cp["attn"]["wv"].astype(cd))
        return None, (ck, cv)

    _, (cks, cvs) = jax.lax.scan(cross_kv, None, params["cross"],
                                 unroll=cfg.unroll_scans or 1)
    Lc = cfg.n_layers
    cache = {
        "k": jnp.zeros((Lc, B, C, K, hd), cd),
        "v": jnp.zeros((Lc, B, C, K, hd), cd),
        "ck": cks,
        "cv": cvs,
        "pos": jnp.asarray(0, jnp.int32),
    }
    # decoder hasn't consumed a token yet: return BOS logits from a zero
    # hidden state convention (callers feed the first real token next).
    x0 = jnp.zeros((B, cfg.d_model), cd)
    return _logits(params, x0, cfg), cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def decode_step(params, token: Array, cache: Dict[str, Any], cfg
                ) -> Tuple[Array, Dict[str, Any]]:
    cd = L.dtype_of(cfg.compute_dtype)
    if cfg.family == "ssm":
        return _decode_ssm(params, token, cache, cfg)
    if cfg.family == "hybrid":
        return _decode_hybrid(params, token, cache, cfg)
    if cfg.is_encoder_decoder:
        return _decode_encdec(params, token, cache, cfg)

    x = params["embed"].astype(cd)[token]  # [B,1,D]
    pos = cache["pos"]

    def block(x, xs):
        lp, ck, cv = xs
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (ck2, cv2) = L.decode_attention(lp["attn"], h, cfg, ck, cv, pos)
        x = x + y
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(_ffn_sub(lp), h, cfg)
        return x, (ck2, cv2)

    x, (ks, vs) = jax.lax.scan(block, x, (params["layers"], cache["k"],
                                          cache["v"]),
                               unroll=cfg.unroll_scans or 1)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return _logits(params, x[:, -1], cfg), new_cache


def _decode_ssm(params, token, cache, cfg):
    cd = L.dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(cd)[token]

    def block(x, xs):
        lp, ssm, conv = xs
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (ssm2, conv2) = mamba2.mamba_decode_step(
            lp["mamba"], h, cfg, ssm, conv
        )
        return x + y, (ssm2, conv2)

    x, (ssm, conv) = jax.lax.scan(
        block, x, (params["layers"], cache["ssm"], cache["conv"]),
        unroll=cfg.unroll_scans or 1,
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    return _logits(params, x[:, -1], cfg), {"ssm": ssm, "conv": conv}


def _decode_hybrid(params, token, cache, cfg):
    cd = L.dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(cd)[token]
    pos = cache["pos"]
    n_inner = cfg.attn_every - 1

    def block(x, xs):
        bp, ck, cv, ssm, conv = xs
        lp = bp["attn_layer"]
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (ck2, cv2) = L.decode_attention(lp["attn"], h, cfg, ck, cv, pos)
        x = x + y
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(_ffn_sub(lp), h, cfg)
        ssms, convs = [], []
        for i in range(1, n_inner + 1):
            mlp_i = bp["mamba_layers"][f"m{i}"]
            h = L.apply_norm(mlp_i["ln1"], x, cfg.norm)
            y, (s2, c2) = mamba2.mamba_decode_step(
                mlp_i["mamba"], h, cfg, ssm[i - 1], conv[i - 1]
            )
            x = x + y
            h = L.apply_norm(mlp_i["ln2"], x, cfg.norm)
            x = x + _apply_ffn(_ffn_sub(mlp_i), h, cfg)
            ssms.append(s2)
            convs.append(c2)
        return x, (ck2, cv2, jnp.stack(ssms), jnp.stack(convs))

    x, (ks, vs, ssm, conv) = jax.lax.scan(
        block, x,
        (params["layers"], cache["k"], cache["v"], cache["ssm"],
         cache["conv"]),
        unroll=cfg.unroll_scans or 1,
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    new_cache = {"k": ks, "v": vs, "ssm": ssm, "conv": conv, "pos": pos + 1}
    return _logits(params, x[:, -1], cfg), new_cache


def _decode_encdec(params, token, cache, cfg):
    cd = L.dtype_of(cfg.compute_dtype)
    B = token.shape[0]
    x = params["embed"].astype(cd)[token]
    pos = cache["pos"]
    x = x + L.sinusoidal_positions(cache["k"].shape[2], cfg.d_model)[
        None, pos, :
    ].astype(cd)

    def block(x, xs):
        lp, cp, ck, cv, xck, xcv = xs
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        y, (ck2, cv2) = L.decode_attention(lp["attn"], h, cfg, ck, cv, pos)
        x = x + y
        h = L.apply_norm(cp["ln"], x, cfg.norm)
        x = x + L.gqa_attention(
            cp["attn"], h, cfg, mask_mode="full", kv_override=(xck, xcv)
        )
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(_ffn_sub(lp), h, cfg)
        return x, (ck2, cv2)

    x, (ks, vs) = jax.lax.scan(
        block, x,
        (params["layers"], params["cross"], cache["k"], cache["v"],
         cache["ck"], cache["cv"]),
        unroll=cfg.unroll_scans or 1,
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    new_cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    return _logits(params, x[:, -1], cfg), new_cache
