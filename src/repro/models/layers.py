"""Transformer building blocks: norms, RoPE, chunked (flash-style) GQA
attention, gated MLPs. Pure functional JAX; params are plain dict pytrees
stacked along the layer axis for lax.scan.

Sharding is decoupled from model math: `shard_hint(x, name)` applies a
with_sharding_constraint only when the distributed runtime installed
activation rules (see repro/distributed/api.py); on CPU tests it is a
no-op.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.distributed.api import shard_hint

Array = jax.Array


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(key, d, kind: str, dtype):
    del key
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x: Array, kind: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings (partial-rotary supported, glm4 style)
# --------------------------------------------------------------------------

def rope_angles(positions: Array, rot_dim: int, theta: float) -> tuple:
    """positions [*, S] -> (cos, sin) with shape [*, S, rot_dim/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array, fraction: float) -> Array:
    """x: [B, S, H, hd]; cos/sin: [B, S, rot/2] or [S, rot/2]."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    if cos.ndim == 2:  # [S, rot/2]
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # [B, S, rot/2]
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2, xp], axis=-1).astype(x.dtype)


def sinusoidal_positions(S: int, d: int) -> Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d)
    )
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --------------------------------------------------------------------------
# attention (GQA, chunked over queries -- flash-style memory profile)
# --------------------------------------------------------------------------

def init_attention(key, cfg, dtype):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, K, hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, K, hd), dtype=dtype),
        "wo": dense_init(
            ks[3], (H, hd, d), scale=1.0 / math.sqrt(H * hd * 2 * cfg.n_layers),
            dtype=dtype,
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    return p


def _mask_value(dtype):
    return jnp.finfo(jnp.float32).min / 2


def attention_scores_chunked(
    q: Array,  # [B, Sq, K, G, hd] grouped queries
    k: Array,  # [B, Skv, K, hd]
    v: Array,  # [B, Skv, K, hd]
    *,
    mask_mode: str,  # "causal" | "prefix" | "full"
    q_offset: Array | int,  # absolute position of q[0]
    prefix_len: int = 0,
    chunk: int = 1024,
    unroll: bool = False,
) -> Array:
    """Exact attention computed in query chunks: peak memory O(chunk*Skv)
    instead of O(Sq*Skv). Equivalent to flash attention at the XLA level;
    the Pallas kernel (kernels/flash_attention.py) implements the same
    contract for TPU."""
    B, Sq, K, G, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, Sq)
    n_chunks = (Sq + chunk - 1) // chunk
    pad = n_chunks * chunk - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qc = q.reshape(B, n_chunks, chunk, K, G, hd)
    kv_pos = jnp.arange(Skv)

    def one_chunk(carry, inputs):
        ci, q_blk = inputs  # q_blk [B, chunk, K, G, hd]
        q_pos = q_offset + ci * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqkgh,bskh->bkgqs", q_blk.astype(jnp.float32) * scale,
            k.astype(jnp.float32),
        )  # [B, K, G, chunk, Skv]
        if mask_mode == "causal":
            m = kv_pos[None, :] <= q_pos[:, None]
        elif mask_mode == "prefix":
            m = (kv_pos[None, :] <= q_pos[:, None]) | (
                kv_pos[None, :] < prefix_len
            )
        else:
            m = jnp.ones((chunk, Skv), bool)
        s = jnp.where(m[None, None, None], s, _mask_value(s.dtype))
        p = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
        return carry, y.astype(v.dtype)

    _, ys = jax.lax.scan(
        one_chunk, None, (jnp.arange(n_chunks), jnp.moveaxis(qc, 1, 0)),
        unroll=n_chunks if unroll else 1,
    )  # ys: [n_chunks, B, chunk, K, G, hd]
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n_chunks * chunk, K, G, hd)
    return y[:, :Sq]


def gqa_attention(
    p,
    x: Array,  # [B, S, D]
    cfg,
    *,
    mask_mode: str = "causal",
    positions: Array | None = None,
    prefix_len: int = 0,
    kv_override: tuple | None = None,  # cross-attention: (k, v) precomputed
) -> Array:
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    cd = dtype_of(cfg.compute_dtype)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
        if "bk" in p:
            k = k + p["bk"].astype(cd)
            v = v + p["bv"].astype(cd)
    else:
        k, v = kv_override

    if positions is None:
        positions = jnp.arange(S)
    if cfg.rope_fraction > 0 and kv_override is None and cfg.n_heads:
        cos, sin = rope_angles(
            positions, int(hd * cfg.rope_fraction), cfg.rope_theta
        )
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)

    q = shard_hint(q, "act_heads")
    qg = q.reshape(B, S, K, G, hd)
    y = attention_scores_chunked(
        qg, k, v,
        mask_mode=mask_mode,
        q_offset=0,
        prefix_len=prefix_len,
        chunk=cfg.attn_chunk,
        unroll=cfg.unroll_scans,
    )
    y = y.reshape(B, S, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(cd))
    return shard_hint(out, "act_btd")


def decode_attention(
    p,
    x: Array,  # [B, 1, D]
    cfg,
    cache_k: Array,  # [B, Sc, K, hd]
    cache_v: Array,
    pos: Array,  # scalar int32: write/read position
) -> tuple:
    """Single-token decode with KV cache (prefill positions < pos valid)."""
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    cd = dtype_of(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.rope_fraction > 0:
        cos, sin = rope_angles(
            pos[None], int(hd * cfg.rope_fraction), cfg.rope_theta
        )
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1
    )
    Sc = cache_k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, 1, K, G, hd)
    s = jnp.einsum(
        "bqkgh,bskh->bkgqs",
        qg.astype(jnp.float32) * scale,
        cache_k.astype(jnp.float32),
    )
    valid = jnp.arange(Sc)[None, :] <= pos
    s = jnp.where(valid[None, None, None], s, _mask_value(s.dtype))
    prob = jax.nn.softmax(s, axis=-1)
    y = jnp.einsum("bkgqs,bskh->bqkgh", prob, cache_v.astype(jnp.float32))
    y = y.reshape(B, 1, H, hd).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(cd))
    return out, (cache_k, cache_v)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str, n_layers: int,
             dtype):
    ks = jax.random.split(key, 3)
    gated = activation in ("swiglu", "geglu")
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_out": dense_init(
            ks[1], (d_ff, d_model), scale=1.0 / math.sqrt(d_ff * 2 * n_layers),
            dtype=dtype,
        ),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def apply_mlp(p, x: Array, activation: str, compute_dtype) -> Array:
    cd = dtype_of(compute_dtype) if isinstance(compute_dtype, str) else compute_dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(cd))
    if activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        h = jax.nn.silu(g) * h
    elif activation == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        h = jax.nn.gelu(g) * h
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    elif activation == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(activation)
    h = shard_hint(h, "act_ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(cd))
