"""Mamba-2 layer: SSD (state-space duality) chunked algorithm
[arXiv:2405.21060].

Recurrence per head (state H in R^{d_state x head_dim}):
    H_t = exp(a_t) * H_{t-1} + dt_t * B_t (x) x_t        a_t = dt_t * A
    y_t = C_t^T H_t + D * x_t
computed chunk-parallel: intra-chunk quadratic attention-like term +
inter-chunk linear state recurrence (a lax.scan over chunk states).

`ssd_chunked` is the pure-jnp reference; kernels/ssd_scan.py provides the
Pallas TPU kernel with the same contract (validated against this).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import shard_hint
from repro.models.layers import dense_init, dtype_of, init_norm, apply_norm

Array = jax.Array


def init_ssm_layer(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = di + 2 * ds
    ks = jax.random.split(key, 6)
    # in_proj -> [z (di) | xBC (di + 2ds) | dt (nh)]
    p = {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * ds + nh), dtype=dtype),
        "conv_w": dense_init(
            ks[1], (cfg.ssm_conv, conv_ch), scale=1.0 / math.sqrt(cfg.ssm_conv),
            dtype=dtype,
        ),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (nh,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": init_norm(ks[3], di, "rmsnorm", dtype),
        "out_proj": dense_init(
            ks[4], (di, d), scale=1.0 / math.sqrt(di * 2 * cfg.n_layers),
            dtype=dtype,
        ),
    }
    return p


def _segsum(a: Array) -> Array:
    """a: [..., L] log-decays -> [..., L, L] with out[l,s] = sum_{r=s+1..l} a_r
    for s <= l, -inf above the diagonal."""
    L = a.shape[-1]
    ci = jnp.cumsum(a, axis=-1)
    diff = ci[..., :, None] - ci[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: Array,   # [B, S, H, P] (pre-multiplied by nothing; dt applied inside)
    dt: Array,  # [B, S, H] (post-softplus)
    A: Array,   # [H] negative
    Bm: Array,  # [B, S, N]
    Cm: Array,  # [B, S, N]
    chunk: int,
    h0: Array | None = None,  # [B, H, N, P] initial state
    unroll: bool = False,
) -> Tuple[Array, Array]:
    """Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt=0 padding is state-neutral: decay exp(0)=1, update dt*x=0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // chunk

    a = (dt * A[None, None, :]).astype(jnp.float32)  # [B,S,H] log-decay
    xd = (x * dt[..., None]).astype(jnp.float32)     # dt-weighted input

    ac = a.reshape(B_, nc, chunk, H)
    xc = xd.reshape(B_, nc, chunk, H, P)
    Bc = Bm.reshape(B_, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, chunk, N).astype(jnp.float32)

    # --- intra-chunk (quadratic in chunk length) ---
    Lmat = jnp.exp(_segsum(jnp.moveaxis(ac, -1, -2)))  # [B,nc,H,l,l]
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)     # [B,nc,l,l]
    y_diag = jnp.einsum(
        "bcls,bchls,bcshp->bclhp", scores, Lmat, xc
    )

    # --- chunk states: S_c = sum_s exp(ci_end - ci_s) B_s (x) xd_s ---
    ci = jnp.cumsum(ac, axis=2)  # [B,nc,l,H]
    decay_to_end = jnp.exp(ci[:, :, -1:, :] - ci)  # [B,nc,l,H]
    S_c = jnp.einsum("bcln,bclh,bclhp->bchnp", Bc, decay_to_end, xc)

    # --- inter-chunk recurrence over chunk states ---
    total = jnp.exp(ci[:, :, -1, :])  # [B,nc,H] decay across each chunk

    def scan_fn(h, inp):
        S_i, tot_i = inp  # [B,H,N,P], [B,H]
        h_new = h * tot_i[..., None, None] + S_i
        return h_new, h  # emit state at chunk START

    if h0 is None:
        h0 = jnp.zeros((B_, H, N, P), jnp.float32)
    hT, h_starts = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)),
        unroll=nc if unroll else 1,
    )
    h_starts = jnp.moveaxis(h_starts, 0, 1)  # [B,nc,H,N,P]

    # --- inter-chunk output: decay from chunk start ---
    decay_from_start = jnp.exp(ci)  # [B,nc,l,H]
    y_off = jnp.einsum(
        "bcln,bclh,bchnp->bclhp", Cc, decay_from_start, h_starts
    )

    y = (y_diag + y_off).reshape(B_, S_pad, H, P)[:, :S]
    return y, hT


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv1d. x: [B,S,C]; w: [W,C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i][None, None, :].astype(jnp.float32)
    return (out + b[None, None, :].astype(jnp.float32)).astype(x.dtype)


def mamba_forward(
    p, x: Array, cfg, h0=None, conv0=None, return_state: bool = False
):
    """Full-sequence Mamba-2 mixer. x: [B,S,D] -> y [B,S,D].

    If return_state, also returns (ssm_state [B,H,N,P], conv_state
    [B, W-1, C]) for chunked/streaming continuation."""
    B, S, D = x.shape
    cd = dtype_of(cfg.compute_dtype)
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ds]
    dt_raw = zxbcdt[..., 2 * di + 2 * ds :]

    if conv0 is not None:
        xBC_in = jnp.concatenate([conv0.astype(xBC.dtype), xBC], axis=1)
        xBC_conv = _causal_conv(xBC_in, p["conv_w"], p["conv_b"])[
            :, conv0.shape[1] :
        ]
    else:
        xBC_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xBC_conv = jax.nn.silu(xBC_conv.astype(jnp.float32)).astype(cd)

    xs = xBC_conv[..., :di].reshape(B, S, nh, hd)
    Bm = xBC_conv[..., di : di + ds]
    Cm = xBC_conv[..., di + ds :]

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :]
    )
    A = -jnp.exp(p["A_log"])

    y, hT = ssd_chunked(xs.astype(jnp.float32), dt, A, Bm, Cm, cfg.ssm_chunk,
                        h0=h0, unroll=cfg.unroll_scans)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = apply_norm(p["gate_norm"], y.astype(cd), "rmsnorm")
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    out = shard_hint(out, "act_btd")
    if return_state:
        convT = xBC[:, S - (cfg.ssm_conv - 1) :, :]
        return out, (hT, convT)
    return out


def mamba_decode_step(p, x: Array, cfg, ssm_state: Array, conv_state: Array):
    """One-token decode. x: [B,1,D]; ssm_state: [B,H,N,P];
    conv_state: [B, W-1, C]. Returns (y [B,1,D], new states)."""
    B = x.shape[0]
    cd = dtype_of(cfg.compute_dtype)
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ds]  # [B,1,C]
    dt_raw = zxbcdt[..., 2 * di + 2 * ds :]

    conv_in = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    W = cfg.ssm_conv
    xBC_conv = (
        jnp.einsum(
            "bwc,wc->bc", conv_in[:, -W:, :].astype(jnp.float32),
            p["conv_w"].astype(jnp.float32),
        )
        + p["conv_b"].astype(jnp.float32)
    )[:, None, :]
    xBC_conv = jax.nn.silu(xBC_conv).astype(cd)
    new_conv_state = conv_in[:, 1:, :]

    xs = xBC_conv[..., :di].reshape(B, nh, hd)
    Bm = xBC_conv[:, 0, di : di + ds].astype(jnp.float32)
    Cm = xBC_conv[:, 0, di + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw[:, 0, :].astype(jnp.float32) + p["dt_bias"][None, :]
    )  # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])  # [B,H]
    xd = xs.astype(jnp.float32) * dt[..., None]  # [B,H,P]
    upd = jnp.einsum("bn,bhp->bhnp", Bm, xd)
    new_ssm = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, new_ssm)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = apply_norm(p["gate_norm"], y.astype(cd), "rmsnorm")
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    return out, (new_ssm, new_conv_state)
