"""Model assembly for all assigned families.

Layer stacks are homogeneous pytrees stacked on a leading layer axis and
driven by lax.scan (compact HLO => fast 512-way SPMD compiles). Hybrid
(jamba) scans over super-blocks of `attn_every` layers (1 attention +
k mamba, MoE on alternate in-block FFNs).

Losses use a sequence-chunked unembed+cross-entropy so [B,S,V] logits are
never materialized (vocab up to 257k).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import shard_hint
from repro.models import layers as L
from repro.models import mamba2, moe as moe_lib

Array = jax.Array


# --------------------------------------------------------------------------
# per-layer init
# --------------------------------------------------------------------------

def _init_ffn(key, cfg, layer_in_block: int, dtype):
    """FFN params for one layer: dense MLP or MoE (+shared/+dense-residual)."""
    use_moe = cfg.n_experts > 0 and (layer_in_block % cfg.moe_every == (
        cfg.moe_every - 1
    ))
    ks = jax.random.split(key, 3)
    if not use_moe:
        if cfg.d_ff == 0:
            return {}
        return {"mlp": L.init_mlp(
            ks[0], cfg.d_model, cfg.d_ff, cfg.activation, cfg.n_layers, dtype
        )}
    p = {"moe": moe_lib.init_moe(ks[0], cfg, dtype)}
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(
            ks[1], cfg.d_model,
            (cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts,
            cfg.activation, cfg.n_layers, dtype,
        )
    if cfg.moe_dense_residual:
        p["dense_res"] = L.init_mlp(
            ks[2], cfg.d_model, cfg.d_ff, cfg.activation, cfg.n_layers, dtype
        )
    return p


def _apply_ffn(p, x: Array, cfg) -> Array:
    if not p:
        return jnp.zeros_like(x)
    if "mlp" in p:
        return L.apply_mlp(p["mlp"], x, cfg.activation, cfg.compute_dtype)
    y = moe_lib.apply_moe(p["moe"], x, cfg)
    if "shared" in p:
        y = y + L.apply_mlp(p["shared"], x, cfg.activation, cfg.compute_dtype)
    if "dense_res" in p:
        y = y + L.apply_mlp(
            p["dense_res"], x, cfg.activation, cfg.compute_dtype
        )
    return y


def _init_dense_layer(key, cfg, layer_idx: int, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
    }
    p.update(_init_ffn(ks[3], cfg, layer_idx, dtype))
    return p


def _init_ssm_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "mamba": mamba2.init_ssm_layer(ks[1], cfg, dtype),
    }


def _init_hybrid_block(key, cfg, dtype):
    """One super-block: 1 attention layer + (attn_every-1) mamba layers,
    each followed by an FFN; MoE on alternate in-block positions."""
    n_inner = cfg.attn_every
    ks = jax.random.split(key, 2 * n_inner + 1)
    block: Dict[str, Any] = {}
    # position 0: attention
    block["attn_layer"] = {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        **_init_ffn(ks[3], cfg, 0, dtype),
    }
    # positions 1..n-1: mamba layers. FFN type alternates (MoE every
    # `moe_every`), so inner layers are heterogeneous pytrees: keep them
    # as named entries (unrolled inside the block; scan runs over blocks).
    mlayers = {}
    for i in range(1, n_inner):
        kk = jax.random.split(ks[3 + i], 4)
        mlayers[f"m{i}"] = {
            "ln1": L.init_norm(kk[0], cfg.d_model, cfg.norm, dtype),
            "mamba": mamba2.init_ssm_layer(kk[1], cfg, dtype),
            "ln2": L.init_norm(kk[2], cfg.d_model, cfg.norm, dtype),
            **_init_ffn(kk[3], cfg, i, dtype),
        }
    block["mamba_layers"] = mlayers
    return block


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = L.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": L.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(
            ks[2], (cfg.d_model, cfg.vocab_size),
            scale=1.0 / math.sqrt(cfg.d_model), dtype=dtype,
        )

    def stack(fn, n, key):
        keys = jax.random.split(key, n)
        layers = [fn(k) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    if cfg.family == "ssm":
        p["layers"] = stack(
            lambda k: _init_ssm_layer(k, cfg, dtype), cfg.n_layers, ks[3]
        )
    elif cfg.family == "hybrid":
        n_blocks = cfg.n_layers // cfg.attn_every
        p["layers"] = stack(
            lambda k: _init_hybrid_block(k, cfg, dtype), n_blocks, ks[3]
        )
    else:
        # dense / moe / vlm decoder stacks (moe_every folds into layer idx:
        # with moe_every==1 every layer is MoE; ==2 scan over pairs)
        if cfg.n_experts and cfg.moe_every > 1:
            def pair(k):
                kk = jax.random.split(k, cfg.moe_every)
                layers = [
                    _init_dense_layer(kk[i], cfg, i, dtype)
                    for i in range(cfg.moe_every)
                ]
                return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
            p["layers"] = stack(pair, cfg.n_layers // cfg.moe_every, ks[3])
        else:
            p["layers"] = stack(
                lambda k: _init_dense_layer(
                    k, cfg, cfg.moe_every - 1, dtype
                ),
                cfg.n_layers, ks[3],
            )
    if cfg.is_encoder_decoder:
        enc_cfg = dataclasses.replace(cfg, n_experts=0)
        p["encoder"] = stack(
            lambda k: _init_dense_layer(k, enc_cfg, 0, dtype),
            cfg.n_encoder_layers, ks[4],
        )
        p["cross"] = stack(
            lambda k: {
                "ln": L.init_norm(
                    jax.random.fold_in(k, 0), cfg.d_model, cfg.norm, dtype
                ),
                "attn": L.init_attention(jax.random.fold_in(k, 1), cfg, dtype),
            },
            cfg.n_layers, ks[5],
        )
    return p


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _dense_block(x, lp, cfg, mask_mode, prefix_len):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    x = x + L.gqa_attention(
        lp["attn"], h, cfg, mask_mode=mask_mode, prefix_len=prefix_len
    )
    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    x = x + _apply_ffn(
        {k: lp[k] for k in ("mlp", "moe", "shared", "dense_res") if k in lp},
        h, cfg,
    )
    return x


def _ssm_block(x, lp, cfg):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    x = x + mamba2.mamba_forward(lp["mamba"], h, cfg)
    return x


def _hybrid_block(x, bp, cfg, mask_mode, prefix_len):
    x = _dense_block(x, bp["attn_layer"], cfg, mask_mode, prefix_len)
    n_inner = cfg.attn_every - 1
    for i in range(1, n_inner + 1):
        lp = bp["mamba_layers"][f"m{i}"]
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        x = x + mamba2.mamba_forward(lp["mamba"], h, cfg)
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(
            {k: lp[k] for k in ("mlp", "moe", "shared", "dense_res")
             if k in lp}, h, cfg,
        )
    return x


def backbone(params, x: Array, cfg, *, mask_mode="causal", prefix_len=0):
    """Runs the decoder stack on embedded inputs x [B,S,D]."""

    if cfg.family == "ssm":
        def block(x, lp):
            return _ssm_block(x, lp, cfg), None
    elif cfg.family == "hybrid":
        def block(x, lp):
            return _hybrid_block(x, lp, cfg, mask_mode, prefix_len), None
    else:
        def block(x, lp):
            return _dense_block(x, lp, cfg, mask_mode, prefix_len), None

    if cfg.remat == "block":
        from jax.ad_checkpoint import checkpoint_name

        inner = block

        def block(x, lp):
            # Name the carry so the policy saves EXACTLY this bf16 tensor.
            # Without it XLA materialized an extra f32 copy of the whole
            # [L,B,S,D] residual stack for the backward loop (hoisted norm
            # convert); see EXPERIMENTS.md §Perf iteration 1.
            x = checkpoint_name(x, "block_in")
            return inner(x, lp)

        block = jax.checkpoint(
            block,
            policy=jax.checkpoint_policies.save_only_these_names("block_in"),
        )
    x, _ = jax.lax.scan(block, x, params["layers"],
                        unroll=cfg.unroll_scans or 1)
    return L.apply_norm(params["final_norm"], x, cfg.norm)


def encoder_forward(params, frames: Array, cfg) -> Array:
    """Enc-dec encoder over precomputed frame embeddings [B,Ssrc,D]."""
    S = frames.shape[1]
    x = frames + L.sinusoidal_positions(S, cfg.d_model)[None].astype(
        frames.dtype
    )

    def block(x, lp):
        return _dense_block(x, lp, cfg, "full", 0), None

    if cfg.remat == "block":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(block, x, params["encoder"],
                        unroll=cfg.unroll_scans or 1)
    return x


def decoder_forward_encdec(params, tokens: Array, enc_out: Array, cfg):
    """Enc-dec decoder: self-attn (causal) + cross-attn + FFN per layer."""
    cd = L.dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    x = params["embed"].astype(cd)[tokens]
    x = x + L.sinusoidal_positions(S, cfg.d_model)[None].astype(cd)
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    def block(x, lps):
        lp, cp = lps
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        x = x + L.gqa_attention(lp["attn"], h, cfg, mask_mode="causal")
        h = L.apply_norm(cp["ln"], x, cfg.norm)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"].astype(cd))
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"].astype(cd))
        x = x + L.gqa_attention(
            cp["attn"], h, cfg, mask_mode="full", kv_override=(ck, cv)
        )
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _apply_ffn(
            {k: lp[k] for k in ("mlp",) if k in lp}, h, cfg
        )
        return x, None

    if cfg.remat == "block":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(block, x, (params["layers"], params["cross"]),
                        unroll=cfg.unroll_scans or 1)
    return L.apply_norm(params["final_norm"], x, cfg.norm)


# --------------------------------------------------------------------------
# losses (sequence-chunked unembed + CE)
# --------------------------------------------------------------------------

def _unembed_weight(params, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return w  # [D, V]


def chunked_ce_loss(params, x: Array, labels: Array, cfg) -> Tuple[Array, Dict]:
    """x: [B,S,D]; labels [B,S] int32 (-1 = ignore). Never materializes
    [B,S,V]: scans over sequence chunks of cfg.logit_chunk."""
    B, S, D = x.shape
    w = _unembed_weight(params, cfg)
    chunk = min(cfg.logit_chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = jnp.moveaxis(x.reshape(B, n_chunks, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n_chunks, chunk), 1, 0)

    @jax.checkpoint  # recompute chunk logits in backward: never keeps
    def one(carry, inp):  # [B,chunk,V] alive across the scan residuals
        xb, lb = inp  # [B,chunk,D], [B,chunk]
        logits = jnp.einsum(
            "bsd,dv->bsv", xb.astype(jnp.float32), w.astype(jnp.float32)
        )
        logits = shard_hint(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        loss_sum, n = carry
        return (
            loss_sum + jnp.sum((lse - ll) * valid),
            n + jnp.sum(valid),
        ), None

    (loss_sum, n), _ = jax.lax.scan(one, (0.0, 0.0), (xc, lc),
                                    unroll=n_chunks if cfg.unroll_scans
                                    else 1)
    loss = loss_sum / jnp.maximum(n, 1.0)
    return loss, {"loss": loss, "tokens": n}


# --------------------------------------------------------------------------
# top-level entry points
# --------------------------------------------------------------------------

def lm_loss(params, batch: Dict[str, Array], cfg) -> Tuple[Array, Dict]:
    """Causal/prefix-LM/enc-dec training loss."""
    cd = L.dtype_of(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        enc = encoder_forward(params, batch["frames"].astype(cd), cfg)
        x = decoder_forward_encdec(params, batch["tokens"], enc, cfg)
        return chunked_ce_loss(params, x, batch["labels"], cfg)
    if cfg.family == "vlm":
        tok_emb = params["embed"].astype(cd)[batch["tokens"]]
        x = jnp.concatenate([batch["patches"].astype(cd), tok_emb], axis=1)
        x = shard_hint(x, "act_btd")
        x = backbone(
            params, x, cfg, mask_mode="prefix", prefix_len=cfg.prefix_len
        )
        x_text = x[:, cfg.prefix_len :, :]
        return chunked_ce_loss(params, x_text, batch["labels"], cfg)
    x = params["embed"].astype(cd)[batch["tokens"]]
    x = shard_hint(x, "act_btd")
    x = backbone(params, x, cfg, mask_mode="causal")
    return chunked_ce_loss(params, x, batch["labels"], cfg)
