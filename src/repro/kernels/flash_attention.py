"""Flash attention Pallas TPU kernel (GQA, causal / prefix-LM / full).

Canonical TPU-native tiling:
  grid = (B, H, Sq/bq, Skv/bk), dimension_semantics =
  (parallel, parallel, parallel, arbitrary) -- the kv dimension is the
  innermost sequential loop; online-softmax accumulators (m, l, acc) live
  in VMEM scratch and persist across kv steps.

Block shapes are MXU-aligned: bq, bk multiples of 128 (clamped to the
sequence), head_dim padded by the caller to a multiple of 128 if needed.
GQA is expressed in the index_map: query head h reads kv head h*K//H, so
K/V blocks are fetched once per kv-head group without materializing the
head broadcast in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import VMEM, CompilerParams

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,  # [1,1,bq,hd], [1,1,bk,hd], [1,1,bk,hd]
    o_ref,                # [1,1,bq,hd]
    m_ref, l_ref, acc_ref,  # VMEM scratch [bq,1], [bq,1], [bq,hd]
    *, mask_mode: str, prefix_len: int, bq: int, bk: int, nk: int,
    scale: float,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [bq, bk]

    iq = pl.program_id(2)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if mask_mode == "causal":
        mask = k_pos <= q_pos
    elif mask_mode == "prefix":
        mask = (k_pos <= q_pos) | (k_pos < prefix_len)
    else:
        mask = None
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # [bq,1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [bq,bk]
    alpha = jnp.exp(m_prev - m_new)  # [bq,1]
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0, ...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mask_mode", "prefix_len", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, K, Skv, hd]
    v: jax.Array,  # [B, K, Skv, hd]
    *,
    mask_mode: str = "causal",
    prefix_len: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert H % K == 0
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(hd)

    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, mask_mode=mask_mode, prefix_len=prefix_len,
        bq=bq, bk=bk, nk=nk, scale=scale,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, bk, hd),
                lambda b, h, iq, ik, K=K, H=H: (b, h * K // H, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, hd),
                lambda b, h, iq, ik, K=K, H=H: (b, h * K // H, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            VMEM((bq, 1), jnp.float32),
            VMEM((bq, 1), jnp.float32),
            VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
