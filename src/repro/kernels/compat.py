"""Pallas TPU API compatibility shims.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (the
old spelling survives as a deprecated alias for a few releases, and older
releases such as 0.4.x only have the TPU-prefixed name). Feature-detect
once here so every kernel in this package works across the installed
range instead of hard-coding one spelling.

This module is the single place allowed to import
``jax.experimental.pallas.tpu`` (enforced by the ``pltpu-import`` lint
rule in ``repro.analysis``): kernels pull ``CompilerParams`` / ``VMEM`` /
``PrefetchScalarGridSpec`` from here, so an upstream rename costs one
edit instead of one per kernel.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu  # lint: allow=pltpu-import

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # jax <= 0.4.x
    CompilerParams = pltpu.TPUCompilerParams

VMEM = pltpu.VMEM
PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec

__all__ = ["CompilerParams", "VMEM", "PrefetchScalarGridSpec"]
