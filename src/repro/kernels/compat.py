"""Pallas TPU API compatibility shims.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (the
old spelling survives as a deprecated alias for a few releases, and older
releases such as 0.4.x only have the TPU-prefixed name). Feature-detect
once here so every kernel in this package works across the installed
range instead of hard-coding one spelling.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # jax <= 0.4.x
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
