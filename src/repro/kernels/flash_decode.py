"""Flash-decode Pallas TPU kernel: single-query GQA attention over a long
KV cache (the decode_32k / long_500k hot loop).

Decode attention is bandwidth-bound: one query reads the entire cache.
The kernel streams K/V blocks through VMEM once, maintaining online
max/sum accumulators per (batch, head) -- the same partial-softmax
combination the sequence-sharded cache path uses across devices, here
applied across cache blocks within a device.

Layout: q [B, H, hd], k/v [B, S, K, hd], valid length `pos+1` masked via
iota against a scalar-prefetched position. Grid = (B, K, S/bs) with the
cache-block dimension innermost/sequential.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import (
    VMEM,
    CompilerParams,
    PrefetchScalarGridSpec,
)

NEG_INF = -1e30


def _kernel(
    pos_ref,                    # SMEM scalar prefetch: valid length - 1
    q_ref, k_ref, v_ref,        # [1,1,G,hd], [1,bs,1,hd], [1,bs,1,hd]
    o_ref,                      # [1,1,G,hd]
    m_ref, l_ref, acc_ref,      # VMEM scratch [G,1], [G,1], [G,hd]
    *, bs: int, ns: int, scale: float,
):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)    # [bs, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # [G, bs]
    kv_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(kv_pos <= pos_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ib == ns - 1)
    def _finish():
        o_ref[0, 0, ...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(
    q: jax.Array,    # [B, H, hd] single-position queries
    k: jax.Array,    # [B, S, K, hd] cache keys (rotated)
    v: jax.Array,    # [B, S, K, hd]
    pos: jax.Array,  # scalar int32: last valid cache index (inclusive)
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns [B, H, hd] attention outputs over cache[:pos+1]."""
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, K, G, hd)
    grid_spec = PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kh, ib, pos: (b, kh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, kh, ib, pos: (b, ib, kh, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, kh, ib, pos: (b, ib, kh, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, kh, ib, pos: (b, kh, 0, 0)
        ),
        scratch_shapes=[
            VMEM((G, 1), jnp.float32),
            VMEM((G, 1), jnp.float32),
            VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, ns=ns, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_decode",
    )(jnp.asarray(pos, jnp.int32).reshape(1), qg, k, v)
    return out.reshape(B, H, hd)
