"""jit'd public wrappers for the Pallas kernels.

On TPU the real kernels run; everywhere else (this CPU container) they
execute in Pallas interpret mode when `interpret=None` (auto) resolves to
True. The contracts match kernels/ref.py exactly (see tests/test_kernels.py
shape/dtype sweeps).
"""
from __future__ import annotations

import jax

from repro.kernels import carbon_score, flash_attention as fa, ssd_chunk
from repro.kernels import ref


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, mask_mode="causal", prefix_len=0,
                    bq=128, bk=128, interpret=None):
    return fa.flash_attention(
        q, k, v, mask_mode=mask_mode, prefix_len=prefix_len,
        bq=bq, bk=bk, interpret=_auto_interpret(interpret),
    )


def ssd_chunk_intra(a, x, Bm, Cm, *, block_heads=8, interpret=None):
    return ssd_chunk.ssd_chunk_intra(
        a, x, Bm, Cm, block_heads=block_heads,
        interpret=_auto_interpret(interpret),
    )


def carbon_scores(Qc, pc, Qe, pe, Cc, V_Ce, *, block_m=256, block_n=256,
                  interpret=None):
    """Fused score pass. Off-TPU with interpret=None (auto) this lowers
    to the bit-identical jnp reference: interpret mode emulates the
    Pallas grid loop in XLA and is strictly slower than letting XLA
    fuse the reference, so it is a correctness oracle (interpret=True,
    as the parity tests pass), never an auto-selected serving path."""
    if interpret is None and jax.default_backend() != "tpu":
        return ref.carbon_scores_ref(Qc, pc, Qe, pe, Cc, V_Ce)
    return carbon_score.carbon_scores(
        Qc, pc, Qe, pe, Cc, V_Ce, block_m=block_m, block_n=block_n,
        interpret=bool(interpret),
    )


def route_scores(Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce, *, block_m=256,
                 block_l=256, interpret=None):
    """Route-lattice score pass. Dispatch policy differs from the other
    kernels: off-TPU with interpret=None (auto) this lowers to the
    bit-identical jnp reference instead of the interpret-mode kernel --
    interpret mode emulates the grid loop in XLA and is strictly slower
    than the fused-by-XLA reference, so auto-dispatch treats it as a
    correctness oracle, not a serving path (DESIGN.md §WAN transfer).
    Pass interpret=True to force the emulated kernel (parity tests do)."""
    if interpret is None and jax.default_backend() != "tpu":
        return ref.route_scores_ref(Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce)
    from repro.kernels import route_score

    return route_score.route_scores(
        Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce, block_m=block_m,
        block_l=block_l, interpret=bool(interpret),
    )


# re-export oracles for convenience
flash_attention_ref = ref.flash_attention_ref
ssd_chunk_intra_ref = ref.ssd_chunk_intra_ref
carbon_scores_ref = ref.carbon_scores_ref
route_scores_ref = ref.route_scores_ref


def flash_decode(q, k, v, pos, *, block_s=512, interpret=None):
    from repro.kernels import flash_decode as fd

    return fd.flash_decode(
        q, k, v, pos, block_s=block_s, interpret=_auto_interpret(interpret)
    )


flash_decode_ref = ref.flash_decode_ref
