"""Paper-specific Pallas kernel: fused drift-plus-penalty scores +
row-argmin for planetary-scale scheduling instances.

Algorithm 1 needs, per task type m:
  n1(m)   = argmin_n Qc[m,n]
  b(m)    = V*Ce*pe[m] + Qc[m, n1(m)] - Qe[m]    (dispatch score)
and the full processing-score matrix c[m,n] = V*Cc[n]*pc[m,n] - Qc[m,n].

At the paper's scale (M=5, N=5) this is trivial; at framework scale
(M = thousands of workload classes x N = thousands of clouds/pods) the
score pass is a memory-bound O(MN) sweep worth fusing: one HBM read of
Qc/pc produces both the c-scores and the per-row (min, argmin) reduction
without a second pass. Grid tiles N (sequential innermost) with running
min/argmin accumulators in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import VMEM, CompilerParams

POS_INF = 1e30


def _kernel(
    qc_ref, pc_ref, qe_ref, pe_ref, cc_ref,  # [bm,bn] [bm,bn] [bm,1] [bm,1] [1,bn]
    vce_ref,                                  # [1,1] scalar-prefetch-free SMEM-ish
    c_ref, n1_ref, b_ref,                     # [bm,bn] [bm,1] [bm,1]
    min_ref, arg_ref,                         # VMEM scratch [bm,1] each
    *, bn: int, nn: int,
):
    i_n = pl.program_id(1)

    @pl.when(i_n == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, POS_INF)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    qc = qc_ref[...].astype(jnp.float32)   # [bm, bn]
    pc = pc_ref[...].astype(jnp.float32)
    cc = cc_ref[...].astype(jnp.float32)   # [1, bn]
    V_Ce = vce_ref[0, 0]

    # processing scores c[m,n] = V*Cc[n]*pc[m,n] - Qc[m,n] (write-through)
    c_ref[...] = (cc * pc - qc).astype(c_ref.dtype)

    # running row min/argmin of Qc
    blk_min = jnp.min(qc, axis=1, keepdims=True)           # [bm,1]
    blk_arg = jnp.argmin(qc, axis=1).astype(jnp.float32)[:, None] + i_n * bn
    better = blk_min < min_ref[...]
    min_ref[...] = jnp.where(better, blk_min, min_ref[...])
    arg_ref[...] = jnp.where(better, blk_arg, arg_ref[...])

    @pl.when(i_n == nn - 1)
    def _finish():
        qe = qe_ref[...].astype(jnp.float32)  # [bm,1]
        pe = pe_ref[...].astype(jnp.float32)
        n1_ref[...] = arg_ref[...].astype(jnp.int32)
        b_ref[...] = (V_Ce * pe + min_ref[...] - qe).astype(b_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def carbon_scores(
    Qc: jax.Array,  # [M, N]
    pc: jax.Array,  # [M, N]
    Qe: jax.Array,  # [M]
    pe: jax.Array,  # [M]
    Cc: jax.Array,  # [N]
    V_Ce: jax.Array,  # scalar: V * Ce(t)
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
):
    """Returns (c_scores [M,N] f32, n1 [M] int32, b [M] f32).

    Arbitrary M/N: inputs are padded up to the block grid. Padded Qc
    entries are +inf so they can never win the row argmin; padded rows /
    columns are sliced off the outputs before returning.
    """
    M, N = Qc.shape
    bm, bn = min(block_m, M), min(block_n, N)
    Mp, Np = -(-M // bm) * bm, -(-N // bn) * bn
    if (Mp, Np) != (M, N):
        dm, dn = Mp - M, Np - N
        Qc = jnp.pad(Qc, ((0, dm), (0, dn)), constant_values=POS_INF)
        pc = jnp.pad(pc, ((0, dm), (0, dn)), constant_values=1.0)
        Qe = jnp.pad(Qe, (0, dm))
        pe = jnp.pad(pe, (0, dm), constant_values=1.0)
        Cc = jnp.pad(Cc, (0, dn))
    nm, nn = Mp // bm, Np // bn
    c, n1, b = pl.pallas_call(
        functools.partial(_kernel, bn=bn, nn=nn),
        grid=(nm, nn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            pl.BlockSpec((bm, 1), lambda m, n: (m, 0)),
            pl.BlockSpec((bm, 1), lambda m, n: (m, 0)),
            pl.BlockSpec((1, bn), lambda m, n: (0, n)),
            pl.BlockSpec((1, 1), lambda m, n: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            pl.BlockSpec((bm, 1), lambda m, n: (m, 0)),
            pl.BlockSpec((bm, 1), lambda m, n: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
            jax.ShapeDtypeStruct((Mp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        scratch_shapes=[
            VMEM((bm, 1), jnp.float32),
            VMEM((bm, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="carbon_scores",
    )(
        Qc, pc, Qe[:, None], pe[:, None], Cc[None, :],
        jnp.asarray(V_Ce, jnp.float32)[None, None],
    )
    return c[:M, :N], n1[:M, 0], b[:M, 0]
