"""Pure-jnp oracles for every Pallas kernel (same contracts, no tiling)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, K, Skv, hd]
    v: jax.Array,
    *,
    mask_mode: str = "causal",
    prefix_len: int = 0,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    if mask_mode == "causal":
        mask = kpos <= qpos
    elif mask_mode == "prefix":
        mask = (kpos <= qpos) | (kpos < prefix_len)
    else:
        mask = jnp.ones((Sq, Skv), bool)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def ssd_chunk_intra_ref(a, x, Bm, Cm):
    """a [B,nc,l,H]; x [B,nc,l,H,P]; Bm/Cm [B,nc,l,N] ->
    (y_diag [B,nc,l,H,P], S_c [B,nc,H,N,P], total [B,nc,H])."""
    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    l = a.shape[2]
    ci = jnp.cumsum(a, axis=2)
    diff = ci[:, :, :, None, :] - ci[:, :, None, :, :]  # [B,nc,l,l,H]
    tril = jnp.tril(jnp.ones((l, l), bool))
    Lmat = jnp.where(tril[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)
    y = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, Lmat, x)
    decay_end = jnp.exp(ci[:, :, -1:, :] - ci)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bm, decay_end, x)
    total = jnp.exp(ci[:, :, -1, :])
    return y, S_c, total


def carbon_scores_ref(Qc, pc, Qe, pe, Cc, V_Ce):
    """-> (c_scores [M,N], n1 [M] int32, b [M])."""
    Qc = Qc.astype(jnp.float32)
    c = Cc[None, :].astype(jnp.float32) * pc.astype(jnp.float32) - Qc
    n1 = jnp.argmin(Qc, axis=1).astype(jnp.int32)
    qmin = jnp.min(Qc, axis=1)
    b = V_Ce * pe.astype(jnp.float32) + qmin - Qe.astype(jnp.float32)
    return c, n1, b


def route_scores_ref(Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce):
    """-> (route_costs [M,L], l1 [M] int32, b [M]).

    Route-lattice twin of carbon_scores_ref (see kernels/route_score.py):
    rc[m,l] = V*Ct[l]*pt[m,l] + extra[m,l] + Qt[m,l] + Qc[m,dest[l]],
    i.e. transfer carbon on the route + optional anticipated destination
    compute carbon + in-flight backlog + destination backlog. The [M,N,L]
    lattice arrives pre-collapsed through the dest gather (Qcr, extra);
    the op order here is the bit-parity contract for the Pallas kernel.
    """
    rc = (
        VCt[None, :].astype(jnp.float32) * pt.astype(jnp.float32)
        + extra.astype(jnp.float32)
        + Qt.astype(jnp.float32)
        + Qcr.astype(jnp.float32)
    )
    l1 = jnp.argmin(rc, axis=1).astype(jnp.int32)
    rmin = jnp.min(rc, axis=1)
    b = V_Ce * pe.astype(jnp.float32) + rmin - Qe.astype(jnp.float32)
    return rc, l1, b


def flash_decode_ref(q, k, v, pos):
    """q [B,H,hd]; k/v [B,S,K,hd]; attend over cache[:pos+1]."""
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, K, G, hd).astype(jnp.float32) / math.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, kf)
    valid = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vf)
    return out.reshape(B, H, hd).astype(q.dtype)
