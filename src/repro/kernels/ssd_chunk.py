"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

Computes, for each (batch, chunk, head-block):
  y_diag [l, bh, P] -- intra-chunk causal contribution
  S_c    [bh, N, P] -- the chunk's contribution to the running state
  total  [bh]       -- decay across the whole chunk
The cheap inter-chunk recurrence (a linear scan over nc chunk states) and
the off-diagonal output term stay in XLA (see models/mamba2.ssd_chunked);
this kernel replaces the two big quadratic einsums whose Lmat
[B,nc,H,l,l] materialization dominates the memory-bound term.

VMEM budget per grid step (l=256, bh=8, P=64, N=128, fp32):
  xc 0.5MB + L 2MB + scores 0.25MB + y 0.5MB + S_c 0.25MB  << 16MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _kernel(a_ref, x_ref, b_ref, c_ref,        # [1,1,l,bh] [1,1,l,bh,P] [1,1,l,N] [1,1,l,N]
            y_ref, s_ref, tot_ref):            # [1,1,l,bh,P] [1,1,bh,N,P] [1,1,bh]
    a = a_ref[0, 0].astype(jnp.float32)        # [l, bh]
    x = x_ref[0, 0].astype(jnp.float32)        # [l, bh, P]
    Bm = b_ref[0, 0].astype(jnp.float32)       # [l, N]
    Cm = c_ref[0, 0].astype(jnp.float32)       # [l, N]
    l = a.shape[0]

    ci = jnp.cumsum(a, axis=0)                 # [l, bh]
    # scores[i,j] = C_i . B_j  (shared across heads)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )                                          # [l, l]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    tril = ii >= jj

    # per-head decay matrix L[h,i,j] = exp(ci[i,h] - ci[j,h]) on i>=j
    diff = ci[:, None, :] - ci[None, :, :]     # [l, l, bh]
    Lmat = jnp.where(tril[..., None], jnp.exp(diff), 0.0)
    w = scores[..., None] * Lmat               # [l, l, bh]
    # y[i,h,p] = sum_j w[i,j,h] * x[j,h,p]
    y = jnp.einsum("ijh,jhp->ihp", w, x, preferred_element_type=jnp.float32)
    y_ref[0, 0, ...] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(ci[-1:, :] - ci)       # [l, bh]
    xw = x * decay_end[..., None]              # [l, bh, P]
    s_c = jnp.einsum("jn,jhp->hnp", Bm, xw,
                     preferred_element_type=jnp.float32)
    s_ref[0, 0, ...] = s_c.astype(s_ref.dtype)
    tot_ref[0, 0, ...] = jnp.exp(ci[-1, :]).astype(tot_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_heads", "interpret")
)
def ssd_chunk_intra(
    a: jax.Array,   # [B, nc, l, H] log-decays (dt*A)
    x: jax.Array,   # [B, nc, l, H, P] dt-weighted inputs
    Bm: jax.Array,  # [B, nc, l, N]
    Cm: jax.Array,  # [B, nc, l, N]
    *,
    block_heads: int = 8,
    interpret: bool = False,
):
    """Returns (y_diag [B,nc,l,H,P], S_c [B,nc,H,N,P], total [B,nc,H])."""
    B, nc, l, H = a.shape
    P = x.shape[-1]
    N = Bm.shape[-1]
    bh = min(block_heads, H)
    assert H % bh == 0
    nh = H // bh
    grid = (B, nc, nh)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, bh), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, l, bh, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, l, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, bh, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, bh, N, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, bh), lambda b, c, h: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, l, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
        name="ssd_chunk_intra",
    )(a, x, Bm, Cm)
