"""Fused WAN route-scoring kernel (network subsystem, sibling of
carbon_score.py).

`NetworkAwareDPPPolicy` ranks (task-type, route, cloud) triples over a
link graph. With every route's destination fixed by the graph, the
[M, N, L] lattice collapses through the dest gather into an [M, L]
cost matrix

    rc[m,l] = V*Ct[l]*pt[m,l]      (transfer carbon on route l)
            + extra[m,l]           (optional anticipated compute carbon)
            + Qt[m,l]              (in-flight backlog on route l)
            + Qc[m, dest[l]]       (destination cloud backlog)

plus the per-type dispatch score b[m] = V*Ce*pe[m] + min_l rc[m,l]
- Qe[m] and the best route l1[m] = argmin_l rc[m,l]. At fleet scale
(M types x L routes per lane, many lanes) this is a memory-bound O(ML)
sweep: one HBM read of the four [M,L] operands produces the cost matrix
AND the per-row (min, argmin) reduction in a single pass. Grid tiles L
sequentially (innermost) with running min/argmin accumulators in VMEM,
exactly the carbon_scores pattern, so blockwise results are bit-identical
to the jnp reference (min is exact; argmin uses strict < so the first
occurrence wins across blocks, matching jnp.argmin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import VMEM, CompilerParams

POS_INF = 1e30


def _kernel(
    qt_ref, pt_ref, qcr_ref, extra_ref,  # [bm,bl] each
    qe_ref, pe_ref,                      # [bm,1] each
    vct_ref,                             # [1,bl]
    vce_ref,                             # [1,1]
    rc_ref, l1_ref, b_ref,               # [bm,bl] [bm,1] [bm,1]
    min_ref, arg_ref,                    # VMEM scratch [bm,1] each
    *, bl: int, nl: int,
):
    i_l = pl.program_id(1)

    @pl.when(i_l == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, POS_INF)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    qt = qt_ref[...].astype(jnp.float32)      # [bm, bl]
    pt = pt_ref[...].astype(jnp.float32)
    qcr = qcr_ref[...].astype(jnp.float32)
    extra = extra_ref[...].astype(jnp.float32)
    vct = vct_ref[...].astype(jnp.float32)    # [1, bl]
    V_Ce = vce_ref[0, 0]

    # Same op order as route_scores_ref -- the bit-parity contract.
    rc = vct * pt + extra + qt + qcr
    rc_ref[...] = rc.astype(rc_ref.dtype)

    # running row min/argmin of rc
    blk_min = jnp.min(rc, axis=1, keepdims=True)              # [bm,1]
    blk_arg = jnp.argmin(rc, axis=1).astype(jnp.float32)[:, None] + i_l * bl
    better = blk_min < min_ref[...]
    min_ref[...] = jnp.where(better, blk_min, min_ref[...])
    arg_ref[...] = jnp.where(better, blk_arg, arg_ref[...])

    @pl.when(i_l == nl - 1)
    def _finish():
        qe = qe_ref[...].astype(jnp.float32)  # [bm,1]
        pe = pe_ref[...].astype(jnp.float32)
        l1_ref[...] = arg_ref[...].astype(jnp.int32)
        b_ref[...] = (V_Ce * pe + min_ref[...] - qe).astype(b_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_l", "interpret")
)
def route_scores(
    Qt: jax.Array,     # [M, L] in-flight transfer queue
    pt: jax.Array,     # [M, L] transfer energy per task on route l
    Qcr: jax.Array,    # [M, L] destination backlog, Qc[:, dest]
    extra: jax.Array,  # [M, L] anticipated destination compute carbon
    Qe: jax.Array,     # [M]
    pe: jax.Array,     # [M]
    VCt: jax.Array,    # [L] V * link-region intensity
    V_Ce: jax.Array,   # scalar: V * Ce(t)
    *,
    block_m: int = 256,
    block_l: int = 256,
    interpret: bool = False,
):
    """Returns (route_costs [M,L] f32, l1 [M] int32, b [M] f32).

    Arbitrary M/L: inputs are padded up to the block grid. Padded Qcr
    entries are +inf so a padded route can never win the row argmin;
    padded rows/columns are sliced off the outputs before returning.
    """
    M, L = Qt.shape
    bm, bl = min(block_m, M), min(block_l, L)
    Mp, Lp = -(-M // bm) * bm, -(-L // bl) * bl
    if (Mp, Lp) != (M, L):
        dm, dl = Mp - M, Lp - L
        Qt = jnp.pad(Qt, ((0, dm), (0, dl)))
        pt = jnp.pad(pt, ((0, dm), (0, dl)))
        Qcr = jnp.pad(Qcr, ((0, dm), (0, dl)), constant_values=POS_INF)
        extra = jnp.pad(extra, ((0, dm), (0, dl)))
        Qe = jnp.pad(Qe, (0, dm))
        pe = jnp.pad(pe, (0, dm), constant_values=1.0)
        VCt = jnp.pad(VCt, (0, dl))
    nm, nl = Mp // bm, Lp // bl
    rc, l1, b = pl.pallas_call(
        functools.partial(_kernel, bl=bl, nl=nl),
        grid=(nm, nl),
        in_specs=[
            pl.BlockSpec((bm, bl), lambda m, l: (m, l)),
            pl.BlockSpec((bm, bl), lambda m, l: (m, l)),
            pl.BlockSpec((bm, bl), lambda m, l: (m, l)),
            pl.BlockSpec((bm, bl), lambda m, l: (m, l)),
            pl.BlockSpec((bm, 1), lambda m, l: (m, 0)),
            pl.BlockSpec((bm, 1), lambda m, l: (m, 0)),
            pl.BlockSpec((1, bl), lambda m, l: (0, l)),
            pl.BlockSpec((1, 1), lambda m, l: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bl), lambda m, l: (m, l)),
            pl.BlockSpec((bm, 1), lambda m, l: (m, 0)),
            pl.BlockSpec((bm, 1), lambda m, l: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Lp), jnp.float32),
            jax.ShapeDtypeStruct((Mp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        scratch_shapes=[
            VMEM((bm, 1), jnp.float32),
            VMEM((bm, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="route_scores",
    )(
        Qt, pt, Qcr, extra, Qe[:, None], pe[:, None], VCt[None, :],
        jnp.asarray(V_Ce, jnp.float32)[None, None],
    )
    return rc[:M, :L], l1[:M, 0], b[:M, 0]
