"""Lemma-1 / drift-plus-penalty property tests."""
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skips

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis.extra import numpy as hnp

from repro.core import dpp
from repro.core.policies import CarbonIntensityPolicy, RandomPolicy
from repro.core.queueing import (
    NetworkSpec,
    NetworkState,
    drift_bound_B,
)

M, N = 3, 2


def spec_():
    return NetworkSpec(
        pe=np.array([2.0, 3.0, 4.0], np.float32),
        pc=np.array([[5.0, 6.0], [7.0, 8.0], [9.0, 10.0]], np.float32),
        Pe=40.0,
        Pc=np.array([90.0, 70.0], np.float32),
    )


@given(
    Qe=hnp.arrays(np.float32, (M,), elements=st.integers(0, 100).map(float)),
    Qc=hnp.arrays(np.float32, (M, N), elements=st.integers(0, 100).map(float)),
    a=hnp.arrays(np.float32, (M,), elements=st.integers(0, 15).map(float)),
    Ce=st.integers(0, 700).map(float),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_lemma1_bound_holds(Qe, Qc, a, Ce, seed):
    """Delta(t) + V*C(t) <= B + sum Qe*a + sum b*d + sum c*w  (eq. 17)
    for arbitrary feasible actions, states and arrivals."""
    spec = spec_()
    state = NetworkState(Qe=jnp.asarray(Qe), Qc=jnp.asarray(Qc))
    rng = np.random.default_rng(seed)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    act = RandomPolicy()(
        state, spec, jnp.float32(Ce), Cc, jnp.asarray(a), jax.random.PRNGKey(seed)
    )
    V = jnp.float32(0.05)
    B = drift_bound_B(spec, a_max=np.full(M, 15.0))
    lhs = dpp.drift_plus_penalty(
        state, spec, act, jnp.asarray(a), jnp.float32(Ce), Cc, V
    )
    rhs = dpp.lemma1_rhs(
        state, spec, act, jnp.asarray(a), jnp.float32(Ce), Cc, V, B
    )
    assert float(lhs) <= float(rhs) + 1e-2


def test_policy_minimizes_surrogate_vs_random():
    """Algorithm 1's action never has a larger surrogate (19) value than
    random feasible actions (statistical sanity, 50 trials)."""
    spec = spec_()
    rng = np.random.default_rng(0)
    worse = 0
    for trial in range(50):
        state = NetworkState(
            Qe=jnp.asarray(rng.integers(0, 200, M).astype(np.float32)),
            Qc=jnp.asarray(rng.integers(0, 200, (M, N)).astype(np.float32)),
        )
        Ce = jnp.float32(rng.uniform(0, 700))
        Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
        pol_act = CarbonIntensityPolicy(V=0.05)(state, spec, Ce, Cc, None, None)
        rnd_act = RandomPolicy()(
            state, spec, Ce, Cc, None, jax.random.PRNGKey(trial)
        )
        v_pol = float(dpp.surrogate_value(state, spec, pol_act, Ce, Cc, 0.05))
        v_rnd = float(dpp.surrogate_value(state, spec, rnd_act, Ce, Cc, 0.05))
        if v_pol > v_rnd + 1e-3:
            worse += 1
    assert worse == 0, f"greedy beaten by random in {worse}/50 trials"


def test_scores_definitions():
    spec = spec_()
    state = NetworkState(
        Qe=jnp.array([10.0, 0.0, 5.0]),
        Qc=jnp.array([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]]),
    )
    pe, pc, _, _ = spec.as_arrays()
    V, Ce = jnp.float32(0.1), jnp.float32(100.0)
    Cc = jnp.array([50.0, 60.0])
    b = dpp.dispatch_scores(state, pe, Ce, V)
    c = dpp.processing_scores(state, pc, Cc, V)
    # b[0,0] = V*Ce*pe0 + Qc00 - Qe0 = 0.1*100*2 + 1 - 10 = 11
    np.testing.assert_allclose(float(b[0, 0]), 11.0, rtol=1e-6)
    # c[2,1] = V*Cc1*pc21 - Qc21 = 0.1*60*10 - 6 = 54
    np.testing.assert_allclose(float(c[2, 1]), 54.0, rtol=1e-6)
