"""Score-backend equivalence: CarbonIntensityPolicy(score_backend=
"pallas") must produce BIT-IDENTICAL actions to the jnp reference
backend under jit, across a randomized sweep that includes
non-multiple-of-block M/N (the kernel pads internally)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import CarbonIntensityPolicy
from repro.core.queueing import NetworkSpec, NetworkState

jax.config.update("jax_enable_x64", False)


def _random_instance(rng, M, N):
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=float(rng.uniform(100, 2000)),
        Pc=rng.uniform(100, 5000, N).astype(np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    return spec, state, Ce, Cc


@pytest.mark.parametrize(
    "M,N,bm,bn",
    [
        (5, 5, 256, 256),       # paper size, blocks larger than the array
        (128, 128, 128, 128),   # exact block fit
        (100, 37, 64, 16),      # non-multiple of block in both dims
        (257, 129, 128, 128),   # one row/col past the block boundary
        (300, 200, 128, 64),
    ],
)
@pytest.mark.parametrize("chunk", [8, 512])
def test_pallas_backend_actions_bit_identical(M, N, bm, bn, chunk):
    rng = np.random.default_rng(M * 1000 + N)
    for trial in range(3):
        spec, state, Ce, Cc = _random_instance(rng, M, N)
        ref = CarbonIntensityPolicy(V=0.05, fill_chunk=chunk)
        # score_interpret=True forces the real (emulated) kernel on CPU;
        # the default None auto-dispatches to the reference off-TPU
        # (covered by test_auto_dispatch_matches_reference).
        pal = CarbonIntensityPolicy(
            V=0.05, fill_chunk=chunk, score_backend="pallas",
            score_block_m=bm, score_block_n=bn, score_interpret=True,
        )
        a_ref = jax.jit(lambda s: ref(s, spec, Ce, Cc, None, None))(state)
        a_pal = jax.jit(lambda s: pal(s, spec, Ce, Cc, None, None))(state)
        np.testing.assert_array_equal(
            np.asarray(a_ref.d), np.asarray(a_pal.d),
            err_msg=f"d differs (trial {trial})",
        )
        np.testing.assert_array_equal(
            np.asarray(a_ref.w), np.asarray(a_pal.w),
            err_msg=f"w differs (trial {trial})",
        )


def test_auto_dispatch_matches_reference():
    """With score_interpret=None (auto) the pallas backend lowers to
    whatever serves fastest on this platform (the jnp reference off-TPU,
    the fused kernel on TPU) -- actions must be identical either way."""
    rng = np.random.default_rng(42)
    spec, state, Ce, Cc = _random_instance(rng, 64, 16)
    ref = CarbonIntensityPolicy(V=0.05)
    auto = CarbonIntensityPolicy(V=0.05, score_backend="pallas")
    a_ref = jax.jit(lambda s: ref(s, spec, Ce, Cc, None, None))(state)
    a_auto = jax.jit(lambda s: auto(s, spec, Ce, Cc, None, None))(state)
    np.testing.assert_array_equal(np.asarray(a_ref.d), np.asarray(a_auto.d))
    np.testing.assert_array_equal(np.asarray(a_ref.w), np.asarray(a_auto.w))


def test_unknown_backend_raises():
    pol = CarbonIntensityPolicy(score_backend="nope")
    rng = np.random.default_rng(0)
    spec, state, Ce, Cc = _random_instance(rng, 5, 5)
    with pytest.raises(ValueError, match="score_backend"):
        pol(state, spec, Ce, Cc, None, None)


def test_pallas_backend_inside_simulation():
    """The kernel-backed policy drives the full scan-based simulator."""
    from repro.core import ConstantCarbonSource, UniformArrivals, simulate

    rng = np.random.default_rng(1)
    spec, _, _, _ = _random_instance(rng, 12, 7)
    carbon = ConstantCarbonSource(N=7, Ce=300.0, Cc=250.0)
    arrive = UniformArrivals(M=12, amax=50)
    key = jax.random.PRNGKey(0)
    r_ref = simulate(
        CarbonIntensityPolicy(V=0.05), spec, carbon, arrive, 20, key
    )
    r_pal = simulate(
        CarbonIntensityPolicy(V=0.05, score_backend="pallas",
                              score_block_m=8, score_block_n=8,
                              score_interpret=True),
        spec, carbon, arrive, 20, key,
    )
    np.testing.assert_array_equal(
        np.asarray(r_ref.cum_emissions), np.asarray(r_pal.cum_emissions)
    )
    np.testing.assert_array_equal(
        np.asarray(r_ref.Qe), np.asarray(r_pal.Qe)
    )
