"""Serving-loop tests (repro.serve).

The standing anchors:

* the served trajectory IS the batch trajectory: `make_serve_step`
  reuses `simulate`'s per-slot body and PRNG stream assignment, so
  driving it over t = 0..T-1 matches `simulate` bitwise (per-slot
  backlog, per-slot emissions via the live JSONL events) and exactly
  on the f32 totals;
* latency accounting is deterministic under an injected clock: the
  loop calls it in a fixed pattern (once before the loop, twice per
  slot, once after), percentiles exclude exactly the warmup slots and
  follow `np.percentile` linear interpolation;
* queue-age is FIFO bookkeeping with known answers on hand-built
  arrival/processing sequences;
* the live JSONL/Prometheus export parse-validates and the terminal
  summary event reconciles with the returned ServeReport field for
  field.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CarbonIntensityPolicy,
    NetworkSpec,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
)
from repro.serve import latency_percentiles, serve_loop
from repro.serve.loop import _AgeFifo
from repro.telemetry import validate_jsonl, validate_prometheus

jax.config.update("jax_enable_x64", False)

T = 32
M, N = 6, 3


class FakeClock:
    """Integer-second ticks: every interval is exact in f64, so derived
    latencies are exactly representable and percentile asserts can use
    equality."""

    def __init__(self):
        self.t = 0
        self.calls = 0

    def __call__(self):
        self.calls += 1
        self.t += 1
        return float(self.t)


def _setup():
    rng = np.random.default_rng(3)
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=1e4,
        Pc=rng.uniform(1e3, 1e5, N).astype(np.float32),
    )
    return (
        CarbonIntensityPolicy(V=0.05),
        spec,
        RandomCarbonSource(N=N),
        UniformArrivals(M=M, amax=60),
        jax.random.PRNGKey(7),
    )


class TestLatencyAccounting:
    def test_clock_call_pattern_and_exact_percentiles(self):
        clock = FakeClock()
        pol, spec, cs, ar, key = _setup()
        rep = serve_loop(pol, spec, cs, ar, T, key, warmup=2,
                         clock=clock)
        assert clock.calls == 2 * T + 2
        # one tick before + one after each step => 1 s per decision
        np.testing.assert_array_equal(rep.latency_us, np.full(T, 1e6))
        assert rep.p50_us == rep.p95_us == rep.p99_us == 1e6
        assert rep.mean_us == 1e6
        assert rep.wall_s == 2 * T + 1
        assert rep.slots == T and rep.warmup == 2

    def test_warmup_clamped_on_tiny_runs(self):
        pol, spec, cs, ar, key = _setup()
        rep = serve_loop(pol, spec, cs, ar, 1, key, warmup=5,
                         clock=FakeClock())
        assert rep.warmup == 0 and rep.slots == 1

    def test_percentile_definition(self):
        lat = np.asarray([100.0, 200.0, 300.0, 400.0])
        p50, p95, p99, mean = latency_percentiles(lat)
        assert p50 == np.percentile(lat, 50)
        assert p95 == np.percentile(lat, 95)
        assert p99 == np.percentile(lat, 99)
        assert mean == lat.mean()


class TestBatchParity:
    def test_served_trajectory_matches_simulate(self, tmp_path):
        pol, spec, cs, ar, key = _setup()
        rep = serve_loop(pol, spec, cs, ar, T, key, warmup=2,
                         clock=FakeClock(), outdir=tmp_path,
                         stem="parity", flush_every=8)
        res = simulate(pol, spec, cs, ar, T, key)
        backlog = np.asarray(jax.vmap(
            lambda qe, qc: jnp.sum(qe) + jnp.sum(qc)
        )(res.Qe, res.Qc))
        np.testing.assert_array_equal(rep.backlog, backlog)
        assert rep.tasks_dispatched == float(res.dispatched.sum())
        assert rep.tasks_processed == float(res.processed.sum())
        np.testing.assert_allclose(
            rep.total_emissions, float(res.emissions.sum()), rtol=1e-6
        )
        # per-slot emissions round-trip through the live JSONL bitwise
        events = [
            json.loads(line)
            for line in (tmp_path / "parity.jsonl").read_text()
            .splitlines()
        ]
        slots = [e for e in events if e["event"] == "slot"]
        assert len(slots) == T
        np.testing.assert_array_equal(
            np.float32([e["emissions"] for e in slots]),
            np.asarray(res.emissions),
        )


class TestQueueAge:
    def test_fifo_known_sequence(self):
        fifo = _AgeFifo()
        # t=0: 10 arrive, none processed -> oldest is age 0
        assert fifo.update(0, 10.0, 0.0) == 0
        # t=1: nothing arrives, 4 processed -> 6 of slot-0 left, age 1
        assert fifo.update(1, 0.0, 4.0) == 1
        # t=2: 5 arrive, 6 processed -> slot-0 drained, 5 of slot-2
        assert fifo.update(2, 5.0, 6.0) == 0
        # t=3: nothing arrives, 5 processed -> empty, age 0
        assert fifo.update(3, 0.0, 5.0) == 0
        assert fifo.update(4, 0.0, 3.0) == 0

    def test_overdrain_never_negative(self):
        fifo = _AgeFifo()
        fifo.update(0, 2.0, 0.0)
        assert fifo.update(1, 0.0, 100.0) == 0

    def test_report_max_queue_age(self):
        pol, spec, cs, ar, key = _setup()
        rep = serve_loop(pol, spec, cs, ar, T, key,
                         clock=FakeClock())
        assert rep.max_queue_age == int(np.max(rep.queue_age))
        assert rep.max_queue_age >= 0


class TestLiveExport:
    def test_outputs_validate_and_summary_reconciles(self, tmp_path):
        pol, spec, cs, ar, key = _setup()
        rep = serve_loop(pol, spec, cs, ar, T, key, warmup=2,
                         clock=FakeClock(), outdir=tmp_path,
                         flush_every=8)
        jsonl = (tmp_path / "serve.jsonl").read_text()
        assert validate_jsonl(jsonl) == T + 1
        assert validate_prometheus(
            (tmp_path / "serve.prom").read_text()) > 0
        summary = json.loads(jsonl.splitlines()[-1])
        assert summary["event"] == "summary"
        assert summary["kind"] == "serve"
        for field in ("slots", "warmup", "tasks_arrived",
                      "tasks_dispatched", "tasks_processed",
                      "total_emissions", "wall_s", "tasks_per_sec",
                      "p50_us", "p95_us", "p99_us", "mean_us",
                      "max_queue_age"):
            assert summary[field] == getattr(rep, field), field

    def test_histogram_wire_format(self, tmp_path):
        pol, spec, cs, ar, key = _setup()
        serve_loop(pol, spec, cs, ar, T, key, warmup=2,
                   clock=FakeClock(), outdir=tmp_path)
        prom = (tmp_path / "serve.prom").read_text()
        assert "# TYPE repro_serve_latency_us histogram" in prom
        assert 'repro_serve_latency_us_bucket{le="+Inf"} 30' in prom
        assert "repro_serve_latency_us_count 30" in prom

    def test_live_percentiles_match_summary(self, tmp_path):
        """The last live prom snapshot is computed from the same
        non-warmup latencies as the end-of-run report."""
        pol, spec, cs, ar, key = _setup()
        rep = serve_loop(pol, spec, cs, ar, T, key, warmup=2,
                         clock=FakeClock(), outdir=tmp_path)
        prom = (tmp_path / "serve.prom").read_text()
        for line in prom.splitlines():
            if line.startswith("repro_serve_latency_p50_us "):
                assert float(line.split()[-1]) == rep.p50_us
                break
        else:
            pytest.fail("p50 gauge missing from live snapshot")


class TestSmokeCLI:
    def test_main_smoke(self, tmp_path, monkeypatch, capsys):
        from repro.serve.loop import main

        monkeypatch.setenv("REPRO_SMOKE", "1")
        rep = main(["--slots", "24", "--outdir", str(tmp_path)])
        assert rep.tasks_arrived >= 1e4
        out = capsys.readouterr().out
        assert "decision latency p50" in out
        assert validate_jsonl(
            (tmp_path / "serve.jsonl").read_text()) == 25
