"""Deadline/SLO layer tests.

The load-bearing claim is the anchor: `deadlines=no_deadlines(M)` is
BIT-IDENTICAL to `deadlines=None` on every simulator variant (plain,
WAN, faulted, faulted WAN, fleet) and on both score backends -- the
deadline layer only changes trajectories when a finite deadline or
shedding is actually configured. Everything else here checks the slot
mechanics (oldest-first drain, expiry, admission) and the behavioral
direction of the deadline-aware policies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fleet_scenarios import (
    build_fleet,
    with_deadlines,
    with_faults,
)
from repro.configs.paper_workloads import V_PAPER, paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    LookaheadDPPPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
)
from repro.core.simulator import simulate_fleet
from repro.deadlines import (
    DeadlineState,
    EDDPolicy,
    SlackThresholdPolicy,
    WaitAwhilePolicy,
    deadline_view,
    init_deadlines,
    make_deadlines,
    no_deadlines,
    stack_deadlines,
    step_deadlines,
)
from repro.faults import StalenessGuardPolicy, make_faults
from repro.forecast import SeasonalNaiveForecaster

T = 96


@pytest.fixture(scope="module")
def setup():
    spec = paper_spec()
    return (
        spec,
        RandomCarbonSource(N=spec.N),
        UniformArrivals(M=spec.M),
        jax.random.PRNGKey(7),
    )


def _assert_bitwise(r0, r1, fields):
    for name in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0, name)),
            np.asarray(getattr(r1, name)), err_msg=name,
        )


# ---------------------------------------------------------------------------
# The anchor: no_deadlines == deadlines-off, bitwise, everywhere.


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_anchor_plain(setup, backend):
    spec, carbon, arrive, key = setup
    pol = CarbonIntensityPolicy(V=V_PAPER, score_backend=backend)
    r0 = simulate(pol, spec, carbon, arrive, T, key)
    r1 = simulate(pol, spec, carbon, arrive, T, key,
                  deadlines=no_deadlines(spec.M))
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "processed",
                             "dispatched", "energy_edge", "energy_cloud"))
    assert float(r1.deadlines.total_missed) == 0.0
    assert float(r1.deadlines.total_shed) == 0.0
    # the age rings shadow Qe exactly
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(r1.deadlines.Qd, axis=-1)), np.asarray(r1.Qe)
    )


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_anchor_wan(setup, backend):
    from repro.network.graph import star_graph
    from repro.network.policy import NetworkAwareDPPPolicy

    spec, carbon, arrive, key = setup
    g = star_graph(spec.M, spec.N, np.random.default_rng(0))
    pol = NetworkAwareDPPPolicy(V=V_PAPER, score_backend=backend)
    r0 = simulate(pol, spec, carbon, arrive, T, key, graph=g)
    r1 = simulate(pol, spec, carbon, arrive, T, key, graph=g,
                  deadlines=no_deadlines(spec.M))
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "Qt", "processed",
                             "energy_transfer"))


def test_anchor_faulted(setup):
    spec, carbon, arrive, key = setup
    fp = make_faults(spec.N, cloud_p_down=0.02, cloud_p_up=0.3,
                     task_p_fail=0.05, telem_p_down=0.1, telem_p_up=0.2)
    pol = StalenessGuardPolicy(inner=CarbonIntensityPolicy(V=V_PAPER))
    r0 = simulate(pol, spec, carbon, arrive, T, key, faults=fp)
    r1 = simulate(pol, spec, carbon, arrive, T, key, faults=fp,
                  deadlines=no_deadlines(spec.M))
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "retry", "failed",
                             "requeued", "backlog"))


def test_anchor_faulted_wan(setup):
    from repro.network.graph import star_graph
    from repro.network.policy import NetworkAwareDPPPolicy

    spec, carbon, arrive, key = setup
    g = star_graph(spec.M, spec.N, np.random.default_rng(0))
    fp = make_faults(spec.N, L=g.L, link_p_down=0.1, link_p_up=0.3,
                     task_p_fail=0.02)
    pol = StalenessGuardPolicy(inner=NetworkAwareDPPPolicy(V=V_PAPER))
    r0 = simulate(pol, spec, carbon, arrive, T, key, graph=g, faults=fp)
    r1 = simulate(pol, spec, carbon, arrive, T, key, graph=g, faults=fp,
                  deadlines=no_deadlines(spec.M))
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "Qt", "retry",
                             "backlog"))


def test_anchor_fleet():
    fleet = build_fleet(["diurnal-slack", "bursty"], per_kind=2,
                        M=4, N=3, Tc=24)
    key = jax.random.PRNGKey(3)
    pol = CarbonIntensityPolicy(V=V_PAPER)
    r0 = simulate_fleet(pol, fleet, 48, key)
    nd = stack_deadlines([no_deadlines(4) for _ in range(fleet.F)])
    r1 = simulate_fleet(pol, fleet._replace(deadlines=nd), 48, key)
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "processed"))
    assert float(jnp.sum(r1.deadlines.missed)) == 0.0


# ---------------------------------------------------------------------------
# Slot mechanics.


def test_oldest_first_drain():
    p = no_deadlines(1, D=4)
    ds = DeadlineState(
        Qd=jnp.asarray([[2.0, 3.0, 1.0, 4.0]]),
        mu=jnp.zeros((1,)),
    )
    # 6 dispatches drain ring 3 (4), ring 2 (1), then 1 from ring 1;
    # rings then age one slot (sticky top).
    nxt, admitted, expired, shed = step_deadlines(
        p, ds, jnp.asarray([6.0]), jnp.asarray([5.0])
    )
    np.testing.assert_array_equal(
        np.asarray(nxt.Qd), [[5.0, 2.0, 2.0, 0.0]]
    )
    assert float(admitted[0]) == 5.0
    assert float(expired[0]) == 0.0 and float(shed[0]) == 0.0


def test_expiry_counts_unserved_tasks():
    # deadline 0: one service opportunity. 3 queued at ring 0, serve 1,
    # the other 2 expire (ring index 0 >= deadline 0 post-drain).
    p = make_deadlines(1, D=4, deadline=0.0)
    ds = DeadlineState(Qd=jnp.asarray([[3.0, 0.0, 0.0, 0.0]]),
                       mu=jnp.zeros((1,)))
    nxt, admitted, expired, shed = step_deadlines(
        p, ds, jnp.asarray([1.0]), jnp.asarray([0.0])
    )
    assert float(expired[0]) == 2.0
    assert float(jnp.sum(nxt.Qd)) == 0.0


def test_admission_sheds_overload_but_cold_estimator_admits():
    p = make_deadlines(1, D=8, deadline=1.0, shed_on=1.0, headroom=1.0)
    # cold estimator (mu = 0): everything admitted, no evidence to shed
    ds = init_deadlines(1, 8)
    nxt, admitted, expired, shed = step_deadlines(
        p, ds, jnp.asarray([0.0]), jnp.asarray([10.0])
    )
    assert float(admitted[0]) == 10.0 and float(shed[0]) == 0.0
    # warm estimator at mu = 2: cap = floor(2 * (1+1)) - queued
    ds = DeadlineState(Qd=nxt.Qd * 0.0, mu=jnp.asarray([2.0]))
    nxt, admitted, expired, shed = step_deadlines(
        p, ds, jnp.asarray([0.0]), jnp.asarray([10.0])
    )
    assert float(admitted[0]) == 4.0 and float(shed[0]) == 6.0


def test_deadline_view_slack_and_due():
    p = make_deadlines(2, D=4, deadline=[2.0, jnp.inf])
    ds = DeadlineState(
        Qd=jnp.asarray([[0.0, 0.0, 1.0, 0.0],
                        [0.0, 0.0, 0.0, 0.0]]),
        mu=jnp.zeros((2,)),
    )
    v = deadline_view(p, ds)
    assert float(v.slack[0]) == 0.0      # oldest at ring 2, deadline 2
    assert float(v.due[0]) == 1.0
    assert not np.isfinite(float(v.slack[1]))  # empty queue
    assert float(v.due[1]) == 0.0


def test_make_deadlines_validates():
    with pytest.raises(ValueError, match="finite deadlines"):
        make_deadlines(2, D=8, deadline=9.0)
    with pytest.raises(ValueError, match="unknown DeadlineParams"):
        make_deadlines(2, deadlnie=3.0)


# ---------------------------------------------------------------------------
# Conservation with expiry + shedding (deterministic twin of the
# hypothesis property).


def test_conservation_with_expiry_and_shedding(setup):
    spec, carbon, arrive, key = setup
    dl = make_deadlines(spec.M, deadline=2.0, shed_on=1.0, headroom=0.9)
    r = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon, arrive,
                 T, key, deadlines=dl)
    led = r.deadlines
    assert float(led.total_missed) > 0.0  # the scenario actually bites
    arrived = float(jnp.sum(led.admitted) + led.total_shed)
    balance = (
        float(jnp.sum(r.Qe[-1]) + jnp.sum(r.Qc[-1]))
        + float(jnp.sum(r.processed))
        + float(led.total_missed) + float(led.total_shed)
    )
    assert arrived == balance  # exact in f32: all integral counts


# ---------------------------------------------------------------------------
# Deadline-aware policies.


def test_slack_threshold_cuts_misses(setup):
    spec, carbon, arrive, key = setup
    dl = make_deadlines(spec.M, deadline=1.0)
    base = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon,
                    arrive, T, key, deadlines=dl)
    aware = simulate(SlackThresholdPolicy(V=V_PAPER), spec, carbon,
                     arrive, T, key, deadlines=dl)
    assert float(aware.deadlines.total_missed) < \
        0.1 * float(base.deadlines.total_missed)


def test_edd_serves_urgent_first(setup):
    spec, carbon, arrive, key = setup
    dl = make_deadlines(spec.M, deadline=1.0)
    base = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon,
                    arrive, T, key, deadlines=dl)
    edd = simulate(EDDPolicy(), spec, carbon, arrive, T, key,
                   deadlines=dl)
    assert float(edd.deadlines.total_missed) < \
        float(base.deadlines.total_missed)


def test_waitawhile_zero_window_matches_lookahead(setup):
    """With W = 0 and nothing ever due, the WaitAwhile gate admits only
    h = 0, where the strictly-cheaper count is 0 < J: every slot is an
    act-now slot and the policy is bitwise LookaheadDPP."""
    spec, carbon, arrive, key = setup
    fc = SeasonalNaiveForecaster(H=4, period=8)
    dl = make_deadlines(spec.M, window=0.0)  # deadlines stay +inf
    r0 = simulate(LookaheadDPPPolicy(V=V_PAPER, H=4), spec, carbon,
                  arrive, T, key, forecaster=fc, deadlines=dl)
    r1 = simulate(WaitAwhilePolicy(V=V_PAPER, H=4), spec, carbon,
                  arrive, T, key, forecaster=fc, deadlines=dl)
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "processed"))


def test_shedding_bounds_overload_backlog():
    fleet = build_fleet(["overload"], per_kind=2, M=4, N=3, Tc=24)
    key = jax.random.PRNGKey(5)
    pol = CarbonIntensityPolicy(V=V_PAPER)
    doomed = with_deadlines(fleet, "tight-uniform")
    shed = with_deadlines(fleet, "shed-overload")
    r0 = simulate_fleet(pol, doomed, 96, key)
    r1 = simulate_fleet(pol, shed, 96, key)
    assert float(jnp.sum(r1.deadlines.shed)) > 0.0
    assert float(jnp.sum(r1.deadlines.missed)) < \
        float(jnp.sum(r0.deadlines.missed))


def test_guard_composes_with_deadline_policies():
    """StalenessGuard forwards deadline_view: the guarded slack policy
    under faults + deadlines runs and still cuts misses vs the guarded
    deadline-blind baseline."""
    fleet = build_fleet(["diurnal"], per_kind=2, M=4, N=3, Tc=24)
    fleet = with_faults(fleet, "telemetry-brownout")
    fleet = with_deadlines(fleet, "tight-uniform")
    key = jax.random.PRNGKey(11)
    base = simulate_fleet(
        StalenessGuardPolicy(inner=CarbonIntensityPolicy(V=V_PAPER)),
        fleet, 96, key)
    aware = simulate_fleet(
        StalenessGuardPolicy(inner=SlackThresholdPolicy(V=V_PAPER)),
        fleet, 96, key)
    assert float(jnp.sum(aware.deadlines.missed)) < \
        float(jnp.sum(base.deadlines.missed))


# ---------------------------------------------------------------------------
# Telemetry integration (satellite: monitors + parity).


def test_telemetry_off_parity_with_deadlines_on(setup):
    from repro.telemetry import TelemetryConfig

    spec, carbon, arrive, key = setup
    dl = make_deadlines(spec.M, deadline=1.0)
    pol = SlackThresholdPolicy(V=V_PAPER)
    r0 = simulate(pol, spec, carbon, arrive, T, key, deadlines=dl)
    r1 = simulate(pol, spec, carbon, arrive, T, key, deadlines=dl,
                  telemetry=TelemetryConfig())
    _assert_bitwise(r0, r1, ("emissions", "Qe", "Qc", "processed"))
    np.testing.assert_array_equal(
        np.asarray(r0.deadlines.missed), np.asarray(r1.deadlines.missed)
    )
    # and the taps agree with the ledger
    np.testing.assert_array_equal(
        np.asarray(r1.telemetry.missed), np.asarray(r1.deadlines.missed)
    )


def test_deadline_monitors_fire(setup):
    from repro.telemetry import TelemetryConfig
    from repro.telemetry.monitors import MONITORS

    spec, carbon, arrive, key = setup
    k_miss = MONITORS.index("deadline_miss")
    k_shed = MONITORS.index("shed_rate")
    dl = make_deadlines(spec.M, deadline=1.0, shed_on=1.0, headroom=0.5)
    r = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon, arrive,
                 T, key, deadlines=dl, telemetry=TelemetryConfig())
    tel = r.telemetry
    assert int(tel.alert_tripped[k_miss]) == 1
    assert int(tel.alert_tripped[k_shed]) == 1
    assert tel.alert_active.shape[-1] == len(MONITORS)
    # conservation monitor must NOT fire: missed/shed are in the ledger
    k_cons = MONITORS.index("conservation_drift")
    assert int(tel.alert_tripped[k_cons]) == 0
    # a deadline-off run never fires either monitor
    r0 = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon,
                  arrive, T, key, telemetry=TelemetryConfig())
    assert int(r0.telemetry.alert_tripped[k_miss]) == 0
    assert int(r0.telemetry.alert_tripped[k_shed]) == 0


def test_record_summary_bitwise_with_deadlines(setup):
    spec, carbon, arrive, key = setup
    dl = make_deadlines(spec.M, deadline=2.0, shed_on=1.0)
    pol = SlackThresholdPolicy(V=V_PAPER)
    full = simulate(pol, spec, carbon, arrive, T, key, deadlines=dl,
                    record="full")
    summ = simulate(pol, spec, carbon, arrive, T, key, deadlines=dl,
                    record="summary")
    _assert_bitwise(full, summ, ("emissions", "processed", "dispatched"))
    for name in ("missed", "shed", "admitted"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full.deadlines, name)),
            np.asarray(getattr(summ.deadlines, name)), err_msg=name,
        )
    assert summ.deadlines.Qd.shape[0] == 1
    np.testing.assert_array_equal(
        np.asarray(full.deadlines.Qd[-1]),
        np.asarray(summ.deadlines.Qd[-1]),
    )
