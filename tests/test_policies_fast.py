"""Exactness tests for the vectorized fast greedy (§Perf iteration 4)."""
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skips

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.policies import (
    CarbonIntensityPolicy,
    _greedy_fill,
    _greedy_fill_fast,
)
from repro.core.queueing import NetworkSpec, NetworkState, is_feasible


@pytest.mark.parametrize("seed", range(25))
def test_fast_fill_matches_reference(seed):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(2, 128))
    scores = rng.uniform(-100, 50, M).astype(np.float32)
    e = rng.uniform(0.5, 10, M).astype(np.float32)
    caps = rng.integers(0, 50, M).astype(np.float32)
    budget = np.float32(rng.uniform(1, 500))
    a = np.asarray(_greedy_fill(
        jnp.asarray(scores), jnp.asarray(e), jnp.asarray(caps),
        jnp.asarray(budget), True,
    ))
    b = np.asarray(_greedy_fill_fast(
        jnp.asarray(scores), jnp.asarray(e), jnp.asarray(caps),
        jnp.asarray(budget),
    ))
    np.testing.assert_array_equal(a, b)


@given(
    M=st.integers(2, 24),
    budget=st.floats(1.0, 1e4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_fast_fill_property(M, budget, seed):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(-200, 50, M).astype(np.float32)
    e = rng.uniform(0.5, 20, M).astype(np.float32)
    caps = rng.integers(0, 100, M).astype(np.float32)
    a = np.asarray(_greedy_fill(
        jnp.asarray(scores), jnp.asarray(e), jnp.asarray(caps),
        jnp.asarray(np.float32(budget)), True,
    ))
    b = np.asarray(_greedy_fill_fast(
        jnp.asarray(scores), jnp.asarray(e), jnp.asarray(caps),
        jnp.asarray(np.float32(budget)),
    ))
    np.testing.assert_array_equal(a, b)


def test_fast_policy_full_parity_moderate_budgets():
    rng = np.random.default_rng(3)
    M, N = 256, 32
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=5e3,
        Pc=rng.uniform(1e3, 5e4, N).astype(np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 500, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 500, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(300.0)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    a = CarbonIntensityPolicy(V=0.05)(state, spec, Ce, Cc, None, None)
    b = CarbonIntensityPolicy(V=0.05, fast=True)(
        state, spec, Ce, Cc, None, None
    )
    np.testing.assert_array_equal(np.asarray(a.d), np.asarray(b.d))
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    assert bool(is_feasible(spec, b))


def test_fast_policy_feasible_on_extreme_budgets():
    """Huge budgets hit f32 summation-order rounding: counts may differ
    from the reference by O(1), but feasibility and surrogate quality
    must hold (documented tolerance)."""
    from repro.core import dpp

    rng = np.random.default_rng(4)
    M, N = 512, 16
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=5e7,
        Pc=np.full(N, 5e7, np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(300.0)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    a = CarbonIntensityPolicy(V=0.05)(state, spec, Ce, Cc, None, None)
    b = CarbonIntensityPolicy(V=0.05, fast=True)(
        state, spec, Ce, Cc, None, None
    )
    assert bool(is_feasible(spec, b))
    va = float(dpp.surrogate_value(state, spec, a, Ce, Cc, 0.05))
    vb = float(dpp.surrogate_value(state, spec, b, Ce, Cc, 0.05))
    assert vb <= va * (1 - 1e-4) + 1e-4 or abs(va - vb) / abs(va) < 1e-3
