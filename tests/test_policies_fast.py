"""Exactness tests for the chunked top_k greedy fill (§Perf-policy).

`greedy_fill` is the repo's ONE fill engine, so these tests pin it to a
float32 numpy transcription of the sequential Algorithm-1 walk across
every variant (stop_at_first_unfit x literal_edge_budget x sort_key),
chunk sizes that force multi-trip chunking, batched-lane stacking, and
the degenerate corners (zero budget, all-nonnegative scores, single
type, zero caps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test dep: only the @given property test needs it
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on lean containers
    HAVE_HYPOTHESIS = False

from repro.core.policies import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    greedy_fill,
    literal_algorithm1,
)
from repro.core.queueing import NetworkSpec, NetworkState, is_feasible

f32 = np.float32


def seq_fill(scores, e, caps, budget, stop=True, literal=False,
             sort_key=None):
    """float32 numpy transcription of the sequential scan fill the
    engine replaced -- the bit-parity oracle (same op order, so exact
    equality is the contract, not a tolerance)."""
    key = sort_key if sort_key is not None else scores / e
    order = np.argsort(key, kind="stable")
    P = f32(budget)
    stopped = False
    take = np.zeros_like(scores)
    for m in order:
        fits = f32(np.floor(P / e[m]))
        can = (fits > 0) and (scores[m] < 0) and (not stopped)
        t = f32(min(caps[m], fits)) if can else f32(0.0)
        take[m] = t
        if literal:
            if can:
                P = f32(P - f32(fits * e[m]))
            stopped = stopped or fits <= 0
        else:
            P = f32(P - f32(t * e[m]))
            if stop:
                stopped = stopped or fits <= 0
    return take


def _instance(rng, M):
    scores = rng.uniform(-100, 50, M).astype(f32)
    e = rng.uniform(0.5, 10, M).astype(f32)
    caps = rng.integers(0, 50, M).astype(f32)
    budget = f32(rng.uniform(1, 500))
    return scores, e, caps, budget


VARIANTS = [
    ("stop", dict(stop_at_first_unfit=True)),
    ("nostop", dict(stop_at_first_unfit=False)),
    ("literal", dict(literal_edge_budget=True)),
]


@pytest.mark.parametrize("chunk", [3, 64])
@pytest.mark.parametrize("variant", [v for v, _ in VARIANTS],
                         ids=[v for v, _ in VARIANTS])
@pytest.mark.parametrize("seed", range(10))
def test_fill_matches_sequential_oracle(seed, variant, chunk):
    kwargs = dict(VARIANTS)[variant]
    rng = np.random.default_rng(seed)
    M = int(rng.integers(2, 128))
    scores, e, caps, budget = _instance(rng, M)
    want = seq_fill(
        scores, e, caps, budget,
        stop=kwargs.get("stop_at_first_unfit", True),
        literal=kwargs.get("literal_edge_budget", False),
    )
    got = np.asarray(greedy_fill(
        jnp.asarray(scores), jnp.asarray(e), jnp.asarray(caps),
        jnp.asarray(budget), chunk=chunk, **kwargs,
    ))
    np.testing.assert_array_equal(want, got)


def _fill_property_case(M, budget, seed, variant, chunk, degenerate):
    kwargs = dict(VARIANTS)[variant]
    rng = np.random.default_rng(seed)
    scores = rng.uniform(-200, 50, M).astype(f32)
    e = rng.uniform(0.5, 20, M).astype(f32)
    caps = rng.integers(0, 100, M).astype(f32)
    budget = f32(budget)
    if degenerate == "zero-budget":
        budget = f32(0.0)
    elif degenerate == "nonneg-scores":
        scores = np.abs(scores)
    elif degenerate == "zero-caps":
        caps = np.zeros_like(caps)
    want = seq_fill(
        scores, e, caps, budget,
        stop=kwargs.get("stop_at_first_unfit", True),
        literal=kwargs.get("literal_edge_budget", False),
    )
    got = np.asarray(greedy_fill(
        jnp.asarray(scores), jnp.asarray(e), jnp.asarray(caps),
        jnp.asarray(budget), chunk=chunk, **kwargs,
    ))
    np.testing.assert_array_equal(want, got)


DEGENERATES = [None, "zero-budget", "nonneg-scores", "zero-caps"]


@pytest.mark.parametrize("degenerate", DEGENERATES,
                         ids=["plain"] + DEGENERATES[1:])
@pytest.mark.parametrize("variant", [v for v, _ in VARIANTS],
                         ids=[v for v, _ in VARIANTS])
def test_fill_degenerate_corners(variant, degenerate):
    """Deterministic slice of the property test (runs without
    hypothesis): each variant on each degenerate corner, with a chunk
    small enough to force multiple trips and M=1 single-type cases."""
    for seed, M, chunk in [(0, 1, 5), (1, 7, 2), (2, 33, 5), (3, 64, 64)]:
        _fill_property_case(M, 250.0, seed, variant, chunk, degenerate)


if HAVE_HYPOTHESIS:

    @given(
        M=st.integers(1, 40),
        budget=st.floats(0.0, 1e4),
        seed=st.integers(0, 2**31 - 1),
        variant=st.sampled_from([v for v, _ in VARIANTS]),
        chunk=st.sampled_from([1, 5, 64]),
        degenerate=st.sampled_from(DEGENERATES),
    )
    @settings(max_examples=120, deadline=None)
    def test_fill_property_all_variants(M, budget, seed, variant, chunk,
                                        degenerate):
        _fill_property_case(M, budget, seed, variant, chunk, degenerate)


def test_fill_sort_key_orders_the_walk():
    """QueueLengthPolicy's ordering contract: sort_key overrides the
    score/energy ratio (ties resolve by index, like the stable sort)."""
    rng = np.random.default_rng(17)
    for _ in range(20):
        M = int(rng.integers(1, 80))
        Q = rng.integers(0, 40, M).astype(f32)
        scores = np.where(Q > 0, -Q, f32(1.0)).astype(f32)
        e = rng.uniform(0.5, 10, M).astype(f32)
        budget = f32(rng.uniform(0, 400))
        want = seq_fill(scores, e, Q, budget, stop=False, sort_key=scores)
        got = np.asarray(greedy_fill(
            jnp.asarray(scores), jnp.asarray(e), jnp.asarray(Q),
            jnp.asarray(budget), stop_at_first_unfit=False,
            sort_key=jnp.asarray(scores), chunk=8,
        ))
        np.testing.assert_array_equal(want, got)


def test_fill_batched_lanes_match_per_lane():
    """The stacked [B, M] call (how policies fill edge + N clouds in one
    shot) equals B independent single-lane calls."""
    rng = np.random.default_rng(5)
    B, M = 9, 120
    S = rng.uniform(-100, 50, (B, M)).astype(f32)
    E = rng.uniform(0.5, 10, (B, M)).astype(f32)
    C = rng.integers(0, 50, (B, M)).astype(f32)
    P = rng.uniform(1, 500, B).astype(f32)
    full = np.asarray(greedy_fill(
        jnp.asarray(S), jnp.asarray(E), jnp.asarray(C), jnp.asarray(P),
        chunk=16,
    ))
    for b in range(B):
        one = np.asarray(greedy_fill(
            jnp.asarray(S[b]), jnp.asarray(E[b]), jnp.asarray(C[b]),
            jnp.asarray(P[b]), chunk=16,
        ))
        np.testing.assert_array_equal(full[b], one)


def test_fill_jits_and_vmaps():
    """The engine composes with jit and vmap (fleet lanes vmap whole
    simulations over it)."""
    rng = np.random.default_rng(2)
    M, B = 50, 6
    S = rng.uniform(-100, 50, (B, M)).astype(f32)
    E = rng.uniform(0.5, 10, (B, M)).astype(f32)
    C = rng.integers(0, 50, (B, M)).astype(f32)
    P = rng.uniform(1, 500, B).astype(f32)
    direct = np.asarray(greedy_fill(
        jnp.asarray(S), jnp.asarray(E), jnp.asarray(C), jnp.asarray(P),
        chunk=16,
    ))
    vmapped = np.asarray(jax.jit(jax.vmap(
        lambda s, e, c, p: greedy_fill(s, e, c, p, chunk=16)
    ))(jnp.asarray(S), jnp.asarray(E), jnp.asarray(C), jnp.asarray(P)))
    np.testing.assert_array_equal(direct, vmapped)


@pytest.mark.parametrize("variant", [v for v, _ in VARIANTS],
                         ids=[v for v, _ in VARIANTS])
@pytest.mark.parametrize("seed", range(6))
def test_policy_matches_literal_algorithm1_all_variants(seed, variant):
    """Full-policy semantics against the pure-Python Algorithm 1
    transcription, for every fill variant (small instances keep the
    float64 oracle and the float32 engine in exact agreement)."""
    rng = np.random.default_rng(seed + 50)
    M, N = int(rng.integers(1, 8)), int(rng.integers(1, 6))
    spec = NetworkSpec(
        pe=rng.uniform(1.0, 8.0, M).astype(f32),
        pc=rng.uniform(2.0, 100.0, (M, N)).astype(f32),
        Pe=float(rng.uniform(20, 200)),
        Pc=rng.uniform(50, 500, N).astype(f32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 200, M).astype(f32)),
        Qc=jnp.asarray(rng.integers(0, 200, (M, N)).astype(f32)),
    )
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(f32))
    V = 0.05
    stop = variant != "nostop"
    literal = variant == "literal"
    pol = CarbonIntensityPolicy(
        V=V, stop_at_first_unfit=stop, literal_edge_budget=literal,
        fill_chunk=4,
    )
    got = pol(state, spec, Ce, Cc, None, None)
    want = literal_algorithm1(
        state, spec, Ce, Cc, V,
        stop_at_first_unfit=stop, literal_edge_budget=literal,
    )
    np.testing.assert_array_equal(np.asarray(got.d), np.asarray(want.d))
    np.testing.assert_array_equal(np.asarray(got.w), np.asarray(want.w))


@pytest.mark.parametrize("chunk", [8, 64])
def test_policy_parity_across_chunk_sizes(chunk):
    """fill_chunk is a pure performance knob: actions are identical
    whatever the chunking (multi-trip vs single-trip)."""
    rng = np.random.default_rng(3)
    M, N = 256, 32
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(f32),
        pc=rng.uniform(2, 100, (M, N)).astype(f32),
        Pe=5e3,
        Pc=rng.uniform(1e3, 5e4, N).astype(f32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 500, M).astype(f32)),
        Qc=jnp.asarray(rng.integers(0, 500, (M, N)).astype(f32)),
    )
    Ce = jnp.float32(300.0)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(f32))
    a = CarbonIntensityPolicy(V=0.05, fill_chunk=512)(
        state, spec, Ce, Cc, None, None
    )
    b = CarbonIntensityPolicy(V=0.05, fill_chunk=chunk)(
        state, spec, Ce, Cc, None, None
    )
    np.testing.assert_array_equal(np.asarray(a.d), np.asarray(b.d))
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    assert bool(is_feasible(spec, b))


def test_queue_length_policy_feasible_and_chunk_invariant():
    rng = np.random.default_rng(9)
    M, N = 64, 8
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(f32),
        pc=rng.uniform(2, 100, (M, N)).astype(f32),
        Pe=2e3,
        Pc=rng.uniform(5e2, 1e4, N).astype(f32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 500, M).astype(f32)),
        Qc=jnp.asarray(rng.integers(0, 500, (M, N)).astype(f32)),
    )
    a = QueueLengthPolicy(fill_chunk=7)(
        state, spec, jnp.float32(0.0), jnp.zeros(N), None, None
    )
    b = QueueLengthPolicy(fill_chunk=64)(
        state, spec, jnp.float32(0.0), jnp.zeros(N), None, None
    )
    np.testing.assert_array_equal(np.asarray(a.d), np.asarray(b.d))
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    assert bool(is_feasible(spec, a))


def test_policy_feasible_on_extreme_budgets():
    """Huge budgets used to hit f32 cumsum rounding in the old prefix
    formulation; the chunked engine replays the sequential op order, so
    exact parity with the oracle holds even here -- and feasibility and
    surrogate quality must hold regardless."""
    from repro.core import dpp

    rng = np.random.default_rng(4)
    M, N = 512, 16
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(f32),
        pc=rng.uniform(2, 100, (M, N)).astype(f32),
        Pe=5e7,
        Pc=np.full(N, 5e7, f32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(f32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(f32)),
    )
    Ce = jnp.float32(300.0)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(f32))
    act = CarbonIntensityPolicy(V=0.05)(state, spec, Ce, Cc, None, None)
    assert bool(is_feasible(spec, act))
    v = float(dpp.surrogate_value(state, spec, act, Ce, Cc, 0.05))
    assert np.isfinite(v)
