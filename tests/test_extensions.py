"""Beyond-paper extension tests: oracle bound, threshold ablation,
adaptive-V controller."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_workloads import paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
)
from repro.core.carbon import materialize
from repro.core.extensions import (
    AdaptiveVController,
    ThresholdPolicy,
    oracle_emissions_for_work,
)
from repro.core.queueing import init_state


def _tables(T=300, seed=0):
    carbon = RandomCarbonSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    key = jax.random.PRNGKey(seed)
    ctab = materialize(carbon, T, jax.random.split(key, 3)[0])
    atab = np.stack(
        [np.asarray(arrive(jnp.asarray(t), jax.random.split(key, 3)[1]))
         for t in range(T)]
    )
    return carbon, arrive, key, ctab, atab


def test_oracle_lower_bounds_online_policies():
    """For the SAME consumed energy, the clairvoyant schedule emits less:
    lb(work) <= policy emissions, for both policies."""
    spec = paper_spec()
    T = 300
    carbon, arrive, key, ctab, atab = _tables(T)
    for pol in (CarbonIntensityPolicy(V=0.05), QueueLengthPolicy()):
        r = simulate(pol, spec, carbon, arrive, T, key)
        lb = oracle_emissions_for_work(
            spec, ctab, float(np.sum(r.energy_edge)),
            np.asarray(r.energy_cloud).sum(),
        )
        assert lb <= float(r.cum_emissions[-1]) * 1.001, (
            lb, float(r.cum_emissions[-1]))


def test_online_policy_approaches_its_oracle():
    """Emissions per unit work: the paper's policy lands much closer to
    its clairvoyant bound than the carbon-blind baseline does."""
    spec = paper_spec()
    T = 400
    carbon, arrive, key, ctab, atab = _tables(T)

    def excess(pol):
        r = simulate(pol, spec, carbon, arrive, T, key)
        lb = oracle_emissions_for_work(
            spec, ctab, float(np.sum(r.energy_edge)),
            np.asarray(r.energy_cloud).sum(),
        )
        return float(r.cum_emissions[-1]) / max(lb, 1e-9)

    ex_carbon = excess(CarbonIntensityPolicy(V=0.2))
    ex_queue = excess(QueueLengthPolicy())
    assert ex_carbon < ex_queue
    assert ex_carbon < 2.0, f"carbon policy {ex_carbon:.2f}x its bound"


def test_threshold_policy_unstable_when_too_strict():
    """CI threshold below the typical minimum -> queues blow up linearly:
    the ablation that motivates drift-plus-penalty."""
    spec = paper_spec()
    carbon = RandomCarbonSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    key = jax.random.PRNGKey(0)
    r = simulate(ThresholdPolicy(threshold=5.0), spec, carbon, arrive, 400,
                 key)
    backlog = np.asarray(r.Qc).sum((1, 2)) + np.asarray(r.Qe).sum(1)
    # linear growth: last-quarter mean >> first-quarter mean
    assert backlog[-100:].mean() > 3 * max(backlog[:100].mean(), 1.0)


def test_adaptive_v_holds_backlog_near_target():
    from repro.core.queueing import step as queue_step
    from repro.core.queueing import emissions as emis

    spec = paper_spec()
    carbon = RandomCarbonSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    key = jax.random.PRNGKey(1)
    kc, ka = jax.random.split(key)
    target = 30000.0
    ctrl = AdaptiveVController(target_backlog=target, V=0.001)
    state = init_state(spec.M, spec.N)
    backlogs = []
    for t in range(250):
        Ce, Cc = carbon(jnp.asarray(t), kc)
        a = arrive(jnp.asarray(t), ka)
        act = ctrl.policy()(state, spec, Ce, Cc, a, None)
        state = queue_step(state, act, a)
        backlog = float(state.Qe.sum() + state.Qc.sum())
        backlogs.append(backlog)
        ctrl.update(backlog)
    tail = np.asarray(backlogs[-80:])
    assert tail.mean() < 3 * target
    assert tail.mean() > target / 5
    assert ctrl.v_min < ctrl.V < ctrl.v_max


def test_oracle_horizon_monotone_in_H_and_lower_bounds_every_policy():
    """ISSUE-4 satellite: on one fixed scenario, the clairvoyant-horizon
    oracle (a) is monotone non-increasing in H on every policy's own
    energy profile, and (b) lower-bounds the realized emissions of every
    policy at every horizon."""
    from repro.core.extensions import oracle_emissions_horizon

    spec = paper_spec()
    T = 250
    carbon, arrive, key, ctab, _ = _tables(T, seed=4)
    horizons = [1, 2, 3, 4, 6, 8, 12, 16, 24, None]
    for pol in (
        CarbonIntensityPolicy(V=0.05),
        CarbonIntensityPolicy(V=0.2),
        QueueLengthPolicy(),
        ThresholdPolicy(threshold=250.0),
    ):
        r = simulate(pol, spec, carbon, arrive, T, key)
        realized = float(r.cum_emissions[-1])
        bounds = [
            oracle_emissions_horizon(
                ctab, np.asarray(r.energy_edge),
                np.asarray(r.energy_cloud), horizon=h,
            )
            for h in horizons
        ]
        for b_prev, b_next in zip(bounds, bounds[1:]):
            assert b_next <= b_prev * (1 + 1e-9), (b_prev, b_next)
        for h, b in zip(horizons, bounds):
            assert b <= realized * (1 + 1e-6), (pol, h, b, realized)
        # H=1 re-prices each kWh at its own slot: exactly the realized cost
        assert bounds[0] == pytest.approx(realized, rel=1e-5)


def test_adaptive_v_update_direction_and_clamps():
    """ISSUE-4 satellite: the multiplicative V feedback moves V the
    right way -- backlog above the band drains queues (V down), below
    the band chases carbon (V up), inside the band holds -- and always
    respects [v_min, v_max]."""
    c = AdaptiveVController(target_backlog=100.0, V=0.05, step=1.15,
                            band=0.25)
    v = c.V
    assert c.update(1000.0) < v          # backlog blow-up -> drain
    v = c.V
    assert c.update(1.0) > v             # idle queues -> chase carbon
    v = c.V
    assert c.update(100.0) == v          # inside the band -> hold
    assert c.update(124.9) == v          # band edge (below 1+band)
    assert c.update(75.1) == v           # band edge (above 1-band)

    lo = AdaptiveVController(target_backlog=100.0, V=1e-4)
    for _ in range(10):
        lo.update(1e9)
    assert lo.V == pytest.approx(lo.v_min)

    hi = AdaptiveVController(target_backlog=100.0, V=9.9)
    for _ in range(10):
        hi.update(0.0)
    assert hi.V == pytest.approx(hi.v_max)
