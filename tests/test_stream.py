"""Streaming-telemetry tests (repro.telemetry.stream).

The standing anchors:

* a StreamConfig run produces the SAME values as the equivalent
  TelemetryConfig run -- every result field and every per-slot
  Telemetry series bitwise, on every simulator variant and both score
  backends (the f32 total_* roll-up gauges get 1 ulp of reassociation
  slack: the chunked scan hands XLA a reshaped [T/k, k] reduction);
* the host channel's reassembled series equal the batch frame bitwise
  -- what streamed out IS what the scan computed, in every record mode;
* `follow_run` (the live Prometheus/JSONL consumer) round-trips the
  flushed slices bitwise and its outputs parse-validate;
* fleet streaming tags flushes with the lane id: each lane's channel
  series equals `lane(frame, i)` bitwise;
* streaming OFF is the PR 8 program: `split_telemetry` hands back the
  plain TelemetryConfig and no stream, and the default-path jaxpr
  stays callback-free (the full audit gate lives in repro.analysis;
  here we check the combos this layer registered onto the effectful
  allowlist and that allow_io=False still rejects them).
"""
import jax
import numpy as np
import pytest

from repro.configs import fleet_scenarios
from repro.core import (
    CarbonIntensityPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
    simulate_fleet,
)
from repro.network import NetworkAwareDPPPolicy, star_graph
from repro.faults import make_faults
from repro.telemetry import (
    StreamConfig,
    TelemetryConfig,
    channel,
    follow_run,
    lane,
    reset_channel,
    split_telemetry,
    validate_jsonl,
    validate_prometheus,
)
from repro.telemetry.taps import TapSeries

jax.config.update("jax_enable_x64", False)

T = 48
M, N = 4, 3
K_FLUSH = 16
KINDS = ["plain", "wan", "faulted", "wan-faulted"]

# f32 sums over the [T] series; XLA may reassociate the reduction when
# the series arrives as reshaped [T/k, k] chunks (the series themselves
# are asserted bitwise)
REASSOC_GAUGES = frozenset({
    "total_emissions", "total_arrived", "total_processed",
    "total_failed", "total_wasted",
})


def _setup():
    spec = fleet_scenarios._base(M, N)
    return (
        spec,
        RandomCarbonSource(N=N),
        UniformArrivals(M=M),
        jax.random.PRNGKey(42),
    )


def _run(kind, telemetry, backend="reference", record="full"):
    spec, src, arr, key = _setup()
    interp = True if backend == "pallas" else None
    kw = {}
    if kind in ("wan", "wan-faulted"):
        pol = NetworkAwareDPPPolicy(
            V=0.05, score_backend=backend, score_interpret=interp
        )
        kw["graph"] = star_graph(M, N, np.random.default_rng(7))
        if kind == "wan-faulted":
            kw["faults"] = make_faults(
                N, kw["graph"].L, task_p_fail=0.1,
                link_p_down=0.2, link_p_up=0.5, link_floor=0.0,
            )
    else:
        pol = CarbonIntensityPolicy(
            V=0.05, score_backend=backend, score_interpret=interp
        )
        if kind == "faulted":
            kw["faults"] = make_faults(
                N, task_p_fail=0.1, cloud_p_down=0.1, cloud_p_up=0.5,
                telem_p_down=0.1, telem_p_up=0.5,
            )
    return simulate(pol, spec, src, arr, T, key,
                    telemetry=telemetry, record=record, **kw)


def _assert_result_equal(a, b):
    for field in type(a)._fields:
        if field == "telemetry":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            err_msg=field,
        )
    for field in type(a.telemetry)._fields:
        x = np.asarray(getattr(a.telemetry, field))
        y = np.asarray(getattr(b.telemetry, field))
        if field in REASSOC_GAUGES:
            np.testing.assert_allclose(x, y, rtol=1e-6, err_msg=field)
        else:
            np.testing.assert_array_equal(x, y, err_msg=field)


def _assert_channel_matches(frame, series):
    """Host-reassembled TapSeries vs the batch Telemetry frame."""
    for field in TapSeries._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(series, field)),
            np.asarray(getattr(frame, field)),
            err_msg=field,
        )


class TestSplit:
    def test_none_passthrough(self):
        assert split_telemetry(None) == (None, None)

    def test_plain_config_no_stream(self):
        tcfg = TelemetryConfig()
        assert split_telemetry(tcfg) == (tcfg, None)

    def test_stream_config_splits(self):
        scfg = StreamConfig(flush_every=8, channel="t")
        tcfg, stream = split_telemetry(scfg)
        assert tcfg == scfg.taps and stream is scfg

    def test_flush_every_validated(self):
        with pytest.raises(ValueError):
            StreamConfig(flush_every=0)

    def test_flush_must_divide_horizon(self):
        with pytest.raises(ValueError):
            _run("plain", StreamConfig(flush_every=7, channel="t-div"))

    def test_stride_must_equal_flush(self):
        with pytest.raises(ValueError):
            _run("plain", StreamConfig(flush_every=8, channel="t-str"),
                 record=16)


class TestStreamingParity:
    @pytest.mark.parametrize("kind", KINDS)
    def test_stream_equals_taps(self, kind):
        name = f"t-par-{kind}"
        reset_channel(name)
        r_taps = _run(kind, TelemetryConfig())
        r_stream = _run(
            kind, StreamConfig(flush_every=K_FLUSH, channel=name)
        )
        _assert_result_equal(r_taps, r_stream)
        _assert_channel_matches(
            r_taps.telemetry, channel(name).series(0)
        )

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_both_score_backends(self, backend):
        name = f"t-bk-{backend}"
        reset_channel(name)
        r_taps = _run("plain", TelemetryConfig(), backend=backend)
        r_stream = _run(
            "plain", StreamConfig(flush_every=K_FLUSH, channel=name),
            backend=backend,
        )
        _assert_result_equal(r_taps, r_stream)
        _assert_channel_matches(
            r_taps.telemetry, channel(name).series(0)
        )

    @pytest.mark.parametrize("record", ["full", "summary", K_FLUSH])
    def test_record_modes(self, record):
        name = f"t-rec-{record}"
        reset_channel(name)
        r_taps = _run("plain", TelemetryConfig(), record=record)
        r_stream = _run(
            "plain", StreamConfig(flush_every=K_FLUSH, channel=name),
            record=record,
        )
        _assert_result_equal(r_taps, r_stream)
        _assert_channel_matches(
            r_taps.telemetry, channel(name).series(0)
        )

    def test_flush_chunking_is_value_neutral(self):
        """Different flush cadences stream identical values."""
        a = "t-k8"
        b = "t-k24"
        reset_channel(a)
        reset_channel(b)
        _run("plain", StreamConfig(flush_every=8, channel=a))
        _run("plain", StreamConfig(flush_every=24, channel=b))
        sa = channel(a).series(0)
        sb = channel(b).series(0)
        for field in TapSeries._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(sa, field)),
                np.asarray(getattr(sb, field)), err_msg=field,
            )
        assert len(channel(a).lanes()) == 1


class TestFleetLanes:
    def test_lane_tagged_flushes(self):
        name = "t-fleet"
        reset_channel(name)
        fleet = fleet_scenarios.build_fleet(
            ["diurnal-slack"], per_kind=3, Tc=96, seed=0
        )
        res = simulate_fleet(
            CarbonIntensityPolicy(V=0.05), fleet, T,
            jax.random.PRNGKey(1), record="summary",
            telemetry=StreamConfig(flush_every=K_FLUSH, channel=name),
        )
        ch = channel(name)
        assert sorted(ch.lanes()) == list(range(fleet.F))
        for i in range(fleet.F):
            _assert_channel_matches(
                lane(res.telemetry, i), ch.series(i)
            )


class TestFollowRun:
    def test_live_export_roundtrip(self, tmp_path):
        name = "t-follow"
        reset_channel(name)
        with follow_run(channel=name, outdir=tmp_path) as run:
            r = _run(
                "plain", StreamConfig(flush_every=K_FLUSH, channel=name)
            )
            paths = run.paths
        assert run.slots == T and run.lanes() == [0]
        _assert_channel_matches(r.telemetry, run.series(0))
        events = paths["jsonl"].read_text()
        assert validate_jsonl(events) == T + 1  # slots + summary
        assert validate_prometheus(
            paths["prometheus"].read_text()) > 0
        # live totals reconcile with the batch frame
        tot = run.totals()
        np.testing.assert_allclose(
            tot["total_emissions"],
            float(r.telemetry.total_emissions), rtol=1e-6,
        )

    def test_consumer_without_outdir(self):
        name = "t-mem"
        reset_channel(name)
        run = follow_run(channel=name)
        r = _run(
            "plain", StreamConfig(flush_every=K_FLUSH, channel=name)
        )
        run.close()
        assert run.slots == T
        _assert_channel_matches(r.telemetry, run.series(0))
        assert validate_prometheus(run.to_prometheus()) > 0


class TestAuditAllowlist:
    def test_streaming_combos_registered(self):
        from repro.analysis import audit

        names = {c.name for c in audit.iter_combos()}
        assert audit.EFFECTFUL_ALLOWLIST, "no streaming combos registered"
        assert audit.EFFECTFUL_ALLOWLIST <= names
        assert any("+stream" in n for n in audit.EFFECTFUL_ALLOWLIST)

    def test_allowlist_gates_io(self):
        from repro.analysis import audit

        combo = next(
            c for c in audit.iter_combos()
            if c.name in audit.EFFECTFUL_ALLOWLIST
        )
        assert audit.audit_combo(combo, allow_io=True) == []
        findings = audit.audit_combo(combo, allow_io=False)
        assert findings and all(
            f.check == "effects" for f in findings
        )

    def test_default_path_still_pure(self):
        from repro.analysis import audit

        combo = next(
            c for c in audit.iter_combos()
            if c.name not in audit.EFFECTFUL_ALLOWLIST
        )
        assert audit.audit_combo(combo, allow_io=False) == []
