"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus a gradient step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import build_model

ARCHS = list(registry.ARCH_IDS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(aid):
        if aid not in cache:
            cfg = registry.get_smoke_config(aid)
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            cache[aid] = (m, params)
        return cache[aid]

    return get


@pytest.mark.parametrize("aid", ARCHS)
def test_forward_loss_finite(built, aid):
    m, params = built(aid)
    batch = m.dummy_batch(jax.random.PRNGKey(1), 32, 2)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("aid", ARCHS)
def test_grad_step_finite(built, aid):
    m, params = built(aid)
    batch = m.dummy_batch(jax.random.PRNGKey(2), 16, 2)
    grads = jax.jit(jax.grad(lambda p: m.loss(p, batch)[0]))(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    # at least some gradient signal everywhere except masked pads
    total = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert total > 0


@pytest.mark.parametrize("aid", ARCHS)
def test_prefill_decode_shapes(built, aid):
    m, params = built(aid)
    cfg = m.cfg
    batch = m.dummy_batch(jax.random.PRNGKey(3), 32, 2)
    batch.pop("labels", None)
    if cfg.is_encoder_decoder:
        batch = {"frames": batch["frames"]}
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b, cache_len=48))(
        params, batch
    )
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.ones((2, 1), jnp.int32)
    logits2, cache2 = jax.jit(m.decode_step)(params, tok, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
    if "pos" in cache:
        assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("aid", ARCHS)
def test_param_count_matches_config_formula(built, aid):
    """init() parameter count == registry's analytic total_params (the
    roofline MODEL_FLOPS source) within 2% (analytic skips norms/biases)."""
    m, params = built(aid)
    n_actual = sum(x.size for x in jax.tree.leaves(params))
    n_formula = m.cfg.total_params()
    # account for expert padding in the actual params
    assert abs(n_actual - n_formula) / n_formula < 0.10, (
        n_actual, n_formula
    )


@pytest.mark.parametrize(
    "aid", ["starcoder2_15b", "mamba2_1_3b", "jamba_1_5_large_398b"]
)
def test_determinism(built, aid):
    m, params = built(aid)
    batch = m.dummy_batch(jax.random.PRNGKey(4), 16, 2)
    l1 = float(jax.jit(m.loss)(params, batch)[0])
    l2 = float(jax.jit(m.loss)(params, batch)[0])
    assert l1 == l2


def test_full_configs_param_counts_plausible():
    """Full-size configs land near their nameplate sizes."""
    expect = {
        "starcoder2_15b": (14e9, 17e9),
        "internlm2_20b": (18e9, 22e9),
        "glm4_9b": (8e9, 11e9),
        "qwen1_5_0_5b": (0.4e9, 0.65e9),
        "arctic_480b": (430e9, 530e9),
        "qwen2_moe_a2_7b": (12e9, 16e9),
        "paligemma_3b": (2e9, 3.5e9),
        "seamless_m4t_medium": (0.8e9, 1.6e9),  # backbone only (stub frontend)
        "mamba2_1_3b": (1.0e9, 1.6e9),
        "jamba_1_5_large_398b": (350e9, 440e9),
    }
    for aid, (lo, hi) in expect.items():
        n = registry.get_config(aid).total_params()
        assert lo <= n <= hi, f"{aid}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
