"""Cross-path consistency oracles:

* prefill+decode == full-sequence forward (KV-cache correctness)
* SSD chunked scan == naive step-by-step recurrence (mamba2 correctness)
* MoE capacity dispatch == dense oracle when capacity is ample
* chunked CE == direct CE
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import build_model
from repro.models import mamba2, moe as moe_lib
from repro.models.transformer import chunked_ce_loss


def _next_token_logits_full(m, params, tokens):
    """Logits for the next token after `tokens` via a full forward pass."""
    from repro.models.transformer import backbone
    from repro.models import layers as L

    cfg = m.cfg
    cd = L.dtype_of(cfg.compute_dtype)
    x = params["embed"].astype(cd)[tokens]
    x = backbone(params, x, cfg, mask_mode="causal")
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum(
        "bd,dv->bv", x[:, -1].astype(jnp.float32), w.astype(jnp.float32)
    )


@pytest.mark.parametrize(
    "aid",
    ["starcoder2_15b", "glm4_9b", "qwen1_5_0_5b", "arctic_480b",
     "mamba2_1_3b", "jamba_1_5_large_398b"],
)
def test_prefill_decode_matches_full_forward(aid):
    cfg = registry.get_smoke_config(aid)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    key = jax.random.PRNGKey(5)
    tokens = jax.random.randint(key, (B, S + 4), 0, cfg.vocab_size)

    # path A: prefill on S tokens then 4 decode steps
    logits, cache = m.prefill(
        params, {"tokens": tokens[:, :S]}, cache_len=S + 8
    )
    decode_logits = [logits]
    for t in range(4):
        logits, cache = m.decode_step(params, tokens[:, S + t : S + t + 1],
                                      cache)
        decode_logits.append(logits)

    # path B: full forward at each prefix length
    for t in range(5):
        full = _next_token_logits_full(m, params, tokens[:, : S + t])
        got = decode_logits[t]
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(full), rtol=2e-3, atol=2e-3
        )


def test_ssd_chunked_matches_naive_recurrence():
    B, S, H, P, N = 2, 32, 3, 8, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))

    for chunk in (4, 8, 16, 32):
        y, hT = mamba2.ssd_chunked(x, dt, A, Bm, Cm, chunk)
        # naive recurrence
        h = jnp.zeros((B, H, N, P))
        ys = []
        for t in range(S):
            decay = jnp.exp(dt[:, t] * A[None, :])  # [B,H]
            upd = jnp.einsum(
                "bn,bhp->bhnp", Bm[:, t], x[:, t] * dt[:, t, :, None]
            )
            h = h * decay[..., None, None] + upd
            ys.append(jnp.einsum("bn,bhnp->bhp", Cm[:, t], h))
        y_naive = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_naive), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(hT), np.asarray(h), rtol=2e-4, atol=2e-4
        )


def test_ssd_streaming_state_continuation():
    """Running two halves with carried state == one full pass."""
    B, S, H, P, N = 1, 32, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_full, h_full = mamba2.ssd_chunked(x, dt, A, Bm, Cm, 8)
    y1, h1 = mamba2.ssd_chunked(
        x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], 8
    )
    y2, h2 = mamba2.ssd_chunked(
        x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], 8, h0=h1
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(h2), np.asarray(h_full), rtol=1e-4, atol=1e-4
    )


def test_moe_capacity_matches_dense_when_ample():
    cfg = registry.get_smoke_config("arctic_480b")
    cfg = dataclasses.replace(cfg, moe_path="capacity")
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, ep=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_dense = moe_lib.moe_dense(p, x, cfg)
    # capacity_factor huge -> no token drops -> exact match
    y_cap = moe_lib.moe_capacity(p, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(
        np.asarray(y_cap), np.asarray(y_dense), rtol=1e-4, atol=1e-4
    )


def test_moe_capacity_drops_gracefully():
    cfg = registry.get_smoke_config("arctic_480b")
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, ep=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y = moe_lib.moe_capacity(p, x, cfg, capacity_factor=0.25)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_padded_experts_never_routed():
    cfg = registry.get_smoke_config("qwen2_moe_a2_7b")  # 6 experts, pad->8
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, ep=4)
    assert p["router"].shape[1] == 8  # padded
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    xt = x.reshape(-1, cfg.d_model)
    _, idx = moe_lib._route(p, xt, cfg)
    assert int(jnp.max(idx)) < cfg.n_experts


def test_chunked_ce_matches_direct():
    cfg = registry.get_smoke_config("qwen1_5_0_5b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, D = 2, 13, cfg.d_model  # odd S exercises padding path
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    labels = labels.at[0, :3].set(-1)  # masked positions
    loss, _ = chunked_ce_loss(params, x, labels, cfg)
    w = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             -1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    want = jnp.sum((lse - ll) * valid) / jnp.sum(valid)
    np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)
