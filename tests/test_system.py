"""End-to-end behaviour tests for the paper's system.

The full claim chain: (1) the simulator reproduces the paper's headline
reductions; (2) the serving path generates coherently with cached decode;
(3) artifacts required by the deliverables exist and are self-consistent.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_workloads import V_PAPER, paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UKRegionalTraceSource,
    UniformArrivals,
    simulate,
)

REPO = Path(__file__).resolve().parents[1]


def test_headline_reduction_random():
    spec = paper_spec()
    key = jax.random.PRNGKey(0)
    T = 1500
    carbon = RandomCarbonSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    rc = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon, arrive, T,
                  key)
    rq = simulate(QueueLengthPolicy(), spec, carbon, arrive, T, key)
    red = 1 - float(rc.cum_emissions[-1]) / float(rq.cum_emissions[-1])
    assert 0.50 < red < 0.70  # paper: 0.63


def test_headline_reduction_realworld():
    spec = paper_spec()
    key = jax.random.PRNGKey(0)
    T = 1500
    carbon = UKRegionalTraceSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    rc = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon, arrive, T,
                  key)
    rq = simulate(QueueLengthPolicy(), spec, carbon, arrive, T, key)
    red = 1 - float(rc.cum_emissions[-1]) / float(rq.cum_emissions[-1])
    assert 0.45 < red < 0.65  # paper: 0.54


def test_end_to_end_serving_generates():
    from repro.configs import registry
    from repro.launch.serve import greedy_generate
    from repro.models import build_model

    cfg = registry.get_smoke_config("qwen1_5_0_5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 12)),
        jnp.int32,
    )
    toks = greedy_generate(model, params, prompts, gen_len=6, cache_len=24)
    assert toks.shape == (2, 6)
    assert np.all(np.asarray(toks) >= 0)
    assert np.all(np.asarray(toks) < cfg.vocab_size)
    # greedy decode is deterministic
    toks2 = greedy_generate(model, params, prompts, gen_len=6, cache_len=24)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


@pytest.mark.skipif(
    not (REPO / "artifacts" / "dryrun").exists(),
    reason="dry-run artifacts not generated",
)
def test_dryrun_artifacts_complete_and_consistent():
    from repro.configs import registry

    cells = {}
    for p in (REPO / "artifacts" / "dryrun").glob("*.json"):
        rec = json.loads(p.read_text())
        cells[(rec["arch"], rec["shape"], rec["mesh"],
               rec.get("seq_parallel", False))] = rec

    n_fail = sum(1 for r in cells.values() if r["status"] == "failed")
    assert n_fail == 0, "dry-run failures present"

    for arch in registry.ARCH_IDS:
        cfg = registry.get_config(arch)
        for shape in registry.SHAPES:
            for mesh in ("single", "multi"):
                rec = cells.get((arch, shape, mesh, False))
                assert rec is not None, f"missing cell {arch}/{shape}/{mesh}"
                ok, _ = cfg.supports_shape(shape)
                if ok:
                    assert rec["status"] == "ok"
                    assert rec["cost"]["flops_per_device"] > 0
                else:
                    assert rec["status"] == "skipped"


@pytest.mark.skipif(
    not (REPO / "artifacts" / "roofline.json").exists(),
    reason="roofline not generated",
)
def test_roofline_terms_sane():
    rows = json.loads((REPO / "artifacts" / "roofline.json").read_text())
    assert len(rows) >= 60
    for a in rows:
        assert a["t_compute_s"] >= 0
        assert a["t_memory_s"] >= 0
        assert a["t_collective_s"] >= 0
        assert a["bound"] in ("compute", "memory", "collective")
        assert 0 <= a["roofline_mfu"] <= 1.0
