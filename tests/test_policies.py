"""Policy tests: Algorithm-1 faithfulness, feasibility properties,
optimality gap vs the exact knapsack oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dpp
from repro.core.policies import (
    CarbonIntensityPolicy,
    ExactDPPPolicy,
    QueueLengthPolicy,
    RandomPolicy,
    literal_algorithm1,
)
from repro.core.queueing import NetworkSpec, NetworkState, is_feasible


def make_spec(rng, M, N):
    return NetworkSpec(
        pe=rng.uniform(1.0, 8.0, M).astype(np.float32),
        pc=rng.uniform(2.0, 100.0, (M, N)).astype(np.float32),
        Pe=float(rng.uniform(20, 200)),
        Pc=rng.uniform(50, 500, N).astype(np.float32),
    )


def make_state(rng, M, N, qmax=200):
    return NetworkState(
        Qe=jnp.asarray(rng.integers(0, qmax, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, qmax, (M, N)).astype(np.float32)),
    )


@pytest.mark.parametrize("seed", range(20))
def test_vectorized_matches_literal_algorithm1(seed):
    """The fixed-shape scan implementation == pure-Python transcription."""
    rng = np.random.default_rng(seed)
    M, N = int(rng.integers(1, 7)), int(rng.integers(1, 6))
    spec = make_spec(rng, M, N)
    state = make_state(rng, M, N)
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    V = 0.05
    pol = CarbonIntensityPolicy(V=V)
    got = pol(state, spec, Ce, Cc, None, None)
    want = literal_algorithm1(state, spec, Ce, Cc, V)
    np.testing.assert_allclose(np.asarray(got.d), np.asarray(want.d), atol=0)
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(want.w), atol=0)


@pytest.mark.parametrize(
    "policy",
    [
        CarbonIntensityPolicy(V=0.05),
        CarbonIntensityPolicy(V=0.05, stop_at_first_unfit=False),
        CarbonIntensityPolicy(V=0.05, literal_edge_budget=True),
        QueueLengthPolicy(),
        RandomPolicy(),
    ],
    ids=["alg1", "alg1-nofirstfit", "alg1-literal", "queuelen", "random"],
)
@pytest.mark.parametrize("seed", range(5))
def test_policies_always_feasible(policy, seed):
    rng = np.random.default_rng(seed)
    M, N = int(rng.integers(1, 8)), int(rng.integers(1, 7))
    spec = make_spec(rng, M, N)
    state = make_state(rng, M, N, qmax=1000)
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    a = jnp.asarray(rng.integers(0, 50, M).astype(np.float32))
    act = policy(state, spec, Ce, Cc, a, jax.random.PRNGKey(seed))
    assert bool(is_feasible(spec, act)), (
        np.asarray(act.d),
        np.asarray(act.w),
    )
    # never dispatch/process more than waiting
    assert np.all(np.asarray(act.d).sum(1) <= np.asarray(state.Qe) + 1e-6)
    assert np.all(np.asarray(act.w) <= np.asarray(state.Qc) + 1e-6)


def test_zero_carbon_means_process_everything_affordable():
    """With Cc=0 the processing score is -Qc<0: clouds drain greedily."""
    rng = np.random.default_rng(1)
    spec = make_spec(rng, 2, 1)
    state = NetworkState(
        Qe=jnp.zeros(2), Qc=jnp.asarray([[3.0], [2.0]])
    )
    pol = CarbonIntensityPolicy(V=0.05, stop_at_first_unfit=False)
    act = pol(state, spec, jnp.float32(0.0), jnp.zeros(1), None, None)
    pc = np.asarray(spec.pc)
    # greedy fills by backlog-per-energy until budget exhausted
    spent = float((np.asarray(act.w) * pc).sum())
    assert spent <= float(np.asarray(spec.Pc)[0]) + 1e-4
    assert float(np.asarray(act.w).sum()) > 0


def test_high_carbon_means_idle():
    """If V*C*p > Q everywhere, all scores positive -> do nothing."""
    rng = np.random.default_rng(2)
    spec = make_spec(rng, 3, 2)
    state = make_state(rng, 3, 2, qmax=3)
    pol = CarbonIntensityPolicy(V=100.0)
    act = pol(state, spec, jnp.float32(700.0), jnp.full(2, 700.0), None, None)
    assert float(np.asarray(act.w).sum()) == 0
    assert float(np.asarray(act.d).sum()) == 0


@pytest.mark.parametrize("seed", range(8))
def test_greedy_vs_exact_dpp_gap(seed):
    """Surrogate value (19): with integral energies and grid == budget the
    knapsack DP is exact, so it is at least as good as the greedy, and the
    greedy stays within 15% of the optimum (quantifies Algorithm 1's
    NP-hardness concession on random small instances)."""
    rng = np.random.default_rng(seed + 100)
    M, N = 4, 3
    budget = 96
    spec = NetworkSpec(
        pe=rng.integers(1, 8, M).astype(np.float32),
        pc=rng.integers(2, 20, (M, N)).astype(np.float32),
        Pe=float(budget),
        Pc=np.full(N, float(budget), np.float32),
    )
    state = make_state(rng, M, N, qmax=60)
    Ce = jnp.float32(rng.uniform(0, 300))
    Cc = jnp.asarray(rng.uniform(0, 300, N).astype(np.float32))
    greedy = CarbonIntensityPolicy(V=0.05, stop_at_first_unfit=False)(
        state, spec, Ce, Cc, None, None
    )
    exact = ExactDPPPolicy(V=0.05, grid=budget)(state, spec, Ce, Cc, None, None)
    v_g = float(dpp.surrogate_value(state, spec, greedy, Ce, Cc, 0.05))
    v_e = float(dpp.surrogate_value(state, spec, exact, Ce, Cc, 0.05))
    assert bool(is_feasible(spec, exact))
    assert v_e <= v_g + 1e-3  # exact at least as good
    if v_e < -1e-6:
        assert v_g <= 0.85 * v_e  # greedy within 15% of optimum


def test_queue_length_policy_is_carbon_blind():
    rng = np.random.default_rng(3)
    spec = make_spec(rng, 3, 2)
    state = make_state(rng, 3, 2)
    pol = QueueLengthPolicy()
    a1 = pol(state, spec, jnp.float32(0.0), jnp.zeros(2), None, None)
    a2 = pol(state, spec, jnp.float32(700.0), jnp.full(2, 700.0), None, None)
    np.testing.assert_array_equal(np.asarray(a1.d), np.asarray(a2.d))
    np.testing.assert_array_equal(np.asarray(a1.w), np.asarray(a2.w))


def test_policy_jits_and_vmaps():
    rng = np.random.default_rng(4)
    spec = make_spec(rng, 3, 2)
    state = make_state(rng, 3, 2)
    pol = CarbonIntensityPolicy(V=0.05)
    jitted = jax.jit(lambda s, Ce, Cc: pol(s, spec, Ce, Cc, None, None))
    act = jitted(state, jnp.float32(100.0), jnp.full(2, 100.0))
    assert act.d.shape == (3, 2)
    # vmap over carbon intensities (spatial what-if analysis)
    batch = jax.vmap(lambda Ce: pol(state, spec, Ce, jnp.full(2, 100.0), None, None))(
        jnp.linspace(0.0, 700.0, 8)
    )
    assert batch.w.shape == (8, 3, 2)
