"""Property-based deadline-layer invariants (skipped cleanly when
`hypothesis` is absent), extending the test_faults_properties pattern:

* task conservation WITH expiry and shedding under ARBITRARY fault
  streams -- every run,
    cum(arrived) = Qe + Qc + retry + cum(processed) - cum(failed)
                   + cum(missed) + cum(shed),
  exact in float32 because every term is an integral count (drains and
  expiries move integral ring contents; the admission cap is floored);
* the age rings re-sum to the edge queue exactly, under any stream;
* record="summary" scalar series (ledger included) are bitwise-equal
  to record="full" with the deadline layer threaded through the carry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import fleet_scenarios  # noqa: E402
from repro.core import (  # noqa: E402
    QueueLengthPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
)
from repro.deadlines import (  # noqa: E402
    SlackThresholdPolicy,
    make_deadlines,
)
from repro.faults import StalenessGuardPolicy, make_faults  # noqa: E402

jax.config.update("jax_enable_x64", False)

T = 32
M, N = 3, 2

rate = st.floats(0.0, 1.0, allow_nan=False, width=32)


@st.composite
def fault_params(draw):
    return make_faults(
        N,
        cloud_p_down=draw(st.floats(0.0, 0.5, width=32)),
        cloud_p_up=draw(rate),
        brown_p_start=draw(rate),
        brown_p_end=draw(rate),
        brown_floor=draw(st.floats(0.1, 1.0, width=32)),
        task_p_fail=draw(rate),
        telem_p_down=draw(rate),
        telem_p_up=draw(rate),
        backoff_max=float(draw(st.integers(0, 8))),
    )


@st.composite
def deadline_params(draw):
    # per-type deadlines mixing finite values with +inf, random
    # windows, and shedding on/off with sub-unity headroom
    d = [
        float(draw(st.integers(0, 6)))
        if draw(st.booleans()) else np.inf
        for _ in range(M)
    ]
    return make_deadlines(
        M,
        deadline=np.asarray(d, np.float32),
        window=float(draw(st.integers(0, 8))),
        shed_on=1.0 if draw(st.booleans()) else 0.0,
        headroom=draw(st.floats(0.5, 1.2, width=32)),
    )


def _run(fp, dl, seed, policy=None, record="full"):
    spec = fleet_scenarios._base(M, N)
    return simulate(
        policy or QueueLengthPolicy(), spec,
        RandomCarbonSource(N=N), UniformArrivals(M=M),
        T, jax.random.PRNGKey(seed), faults=fp, deadlines=dl,
        record=record,
    )


@settings(max_examples=15, deadline=None)
@given(fp=fault_params(), dl=deadline_params(),
       seed=st.integers(0, 2**31 - 1))
def test_conservation_with_expiry_and_shedding(fp, dl, seed):
    """No fault/deadline mix creates or destroys tasks: admitted+shed
    arrivals are exactly accounted for by queues, completed work,
    failures in flight, expiries and sheds -- bitwise in f32."""
    r = _run(fp, dl, seed)
    led = r.deadlines
    arrived = np.cumsum(np.asarray(led.admitted)) + np.cumsum(
        np.asarray(led.shed)
    )
    accounted = (
        np.asarray(r.backlog)
        + np.cumsum(np.asarray(r.processed))
        - np.cumsum(np.asarray(r.failed))
        + np.cumsum(np.asarray(led.missed))
        + np.cumsum(np.asarray(led.shed))
    )
    np.testing.assert_array_equal(arrived, accounted)
    # age rings shadow the edge queue exactly, every recorded slot
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(led.Qd, axis=-1)), np.asarray(r.Qe)
    )
    # nothing negative or NaN anywhere in the ledger
    for name in ("missed", "shed", "admitted", "Qd"):
        v = np.asarray(getattr(led, name))
        assert np.all(v >= 0.0), name
        assert not np.any(np.isnan(v)), name


@settings(max_examples=8, deadline=None)
@given(fp=fault_params(), dl=deadline_params(),
       seed=st.integers(0, 2**31 - 1))
def test_summary_record_scalars_bitwise_equal_full(fp, dl, seed):
    """record="summary" shares the scan body with record="full" with
    the deadline state in the carry: every scalar series -- ledger
    included -- is bitwise identical; only recording density differs."""
    guard = StalenessGuardPolicy(
        inner=SlackThresholdPolicy(V=0.05)
    )
    full = _run(fp, dl, seed, policy=guard, record="full")
    summ = _run(fp, dl, seed, policy=guard, record="summary")
    for name in type(full)._fields:
        if name in ("Qe", "Qc", "retry", "telemetry", "deadlines"):
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(full, name)),
            np.asarray(getattr(summ, name)), err_msg=name,
        )
    for name in ("missed", "shed", "admitted"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full.deadlines, name)),
            np.asarray(getattr(summ.deadlines, name)), err_msg=name,
        )
    assert summ.deadlines.Qd.shape[0] == 1
    np.testing.assert_array_equal(
        np.asarray(full.deadlines.Qd[-1]),
        np.asarray(summ.deadlines.Qd[-1]),
    )


@settings(max_examples=6, deadline=None)
@given(dl=deadline_params(), seed=st.integers(0, 2**31 - 1))
def test_deadline_policies_conserve_without_faults(dl, seed):
    """The deadline-aware policy keeps exact conservation on the plain
    simulator too (its score perturbations change the schedule, never
    the ledger identities)."""
    spec = fleet_scenarios._base(M, N)
    r = simulate(
        SlackThresholdPolicy(V=0.05), spec,
        RandomCarbonSource(N=N), UniformArrivals(M=M),
        T, jax.random.PRNGKey(seed), deadlines=dl,
    )
    led = r.deadlines
    arrived = float(jnp.sum(led.admitted) + led.total_shed)
    accounted = (
        float(jnp.sum(r.Qe[-1]) + jnp.sum(r.Qc[-1]))
        + float(jnp.sum(r.processed))
        + float(led.total_missed) + float(led.total_shed)
    )
    assert arrived == accounted
