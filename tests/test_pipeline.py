"""Pipeline-parallel skeleton test: shard_map+ppermute schedule == the
sequential oracle, run on 4 placeholder devices in a subprocess."""
import json
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_pipeline_matches_reference_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        from repro.distributed.pipeline import pipeline_apply, \\
            pipeline_reference

        mesh = jax.make_mesh((4,), ("stage",))
        n_stages, n_micro, mb, d = 4, 6, 2, 8
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (n_stages, d, d)) * 0.3
        b = jax.random.normal(jax.random.fold_in(key, 1),
                              (n_stages, d)) * 0.1
        params = {"w": w, "b": b}

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        x = jax.random.normal(jax.random.fold_in(key, 2), (n_micro, mb, d))
        got = pipeline_apply(stage_fn, params, x, mesh)
        want = pipeline_reference(stage_fn, params, x)
        err = float(jnp.max(jnp.abs(got - want)))
        print(json.dumps({"err": err}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 1e-5, rec
