"""Dry-run tooling tests: collective-byte parser, sharding rule engine,
and a miniature (8 fake devices) lower+compile in a subprocess."""
import json
import subprocess
import sys
import textwrap

import pytest


def test_parse_collective_bytes():
    from repro.launch.dryrun import parse_collective_bytes

    hlo = textwrap.dedent("""
      %all-gather.3 = bf16[4,1024,512]{2,1,0} all-gather(%p0), dimensions={0}
      %all-reduce.1 = f32[256,128]{1,0} all-reduce(%p1), to_apply=%sum
      %reduce-scatter.2 = f32[16,64]{1,0} reduce-scatter(%p2), dimensions={0}
      %all-to-all.9 = bf16[8,80,7168]{2,1,0} all-to-all(%p3), dimensions={0}
      %collective-permute.4 = u32[2]{0} collective-permute(%p4)
      %add.5 = f32[2]{0} add(%x, %y)
    """)
    totals, counts = parse_collective_bytes(hlo)
    assert counts["all-gather"] == 1
    assert totals["all-gather"] == 4 * 1024 * 512 * 2
    assert totals["all-reduce"] == 2 * 256 * 128 * 4  # 2x ring weight
    assert totals["reduce-scatter"] == 16 * 64 * 4
    assert totals["all-to-all"] == 8 * 80 * 7168 * 2
    assert totals["collective-permute"] == 2 * 4
    assert counts["all-reduce"] == 1


def test_parse_ignores_non_collectives():
    from repro.launch.dryrun import parse_collective_bytes

    totals, counts = parse_collective_bytes(
        "%dot.1 = f32[128,128]{1,0} dot(%a, %b)\n"
    )
    assert sum(counts.values()) == 0


def test_rule_engine_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as sh

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    eng = sh.RuleEngine(mesh)
    # both divide trivially on a unit mesh
    ns = eng.spec("x", ("data", "model"), (8, 16))
    assert ns.spec == P("data", "model")


def test_param_shardings_cover_all_leaves():
    import jax

    from repro.configs import registry
    from repro.distributed import sharding as sh
    from repro.models import build_model

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for aid in ("qwen1_5_0_5b", "arctic_480b", "jamba_1_5_large_398b",
                "seamless_m4t_medium"):
        cfg = registry.get_smoke_config(aid)
        model = build_model(cfg)
        specs = model.param_specs()
        shardings, fallbacks = sh.param_shardings(mesh, specs, cfg)
        n_leaves = len(jax.tree.leaves(specs))
        n_shard = len(jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")
        ))
        assert n_leaves == n_shard


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """Full lower+compile path on 8 placeholder devices (fast analogue of
    the 512-device production dry-run; exercises env-flag ordering)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import jax.numpy as jnp
        from repro.configs import registry
        from repro.distributed import api as dist_api, sharding as sh
        from repro.models import build_model
        from repro.optim.adamw import AdamW, make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = registry.get_smoke_config("internlm2_20b")
        model = build_model(cfg)
        pspecs = model.param_specs()
        p_shard, _ = sh.param_shardings(mesh, pspecs, cfg)
        opt = AdamW()
        ospecs = jax.eval_shape(opt.init, pspecs)
        o_sh, _ = sh.param_shardings(mesh, ospecs.m, cfg)
        o_shard = type(ospecs)(m=o_sh, v=o_sh,
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        b_shard = sh.batch_shardings(mesh, batch)
        fn = make_train_step(model, opt)
        flops = {}
        for sp in (False, True):  # baseline + sequence-parallel rules
            rules = sh.activation_rule_table(mesh, cfg, seq_parallel=sp)
            with mesh, dist_api.activation_rules(rules, mesh=mesh,
                                                 dp_axes=("data",)):
                compiled = jax.jit(
                    fn, in_shardings=(p_shard, o_shard, b_shard),
                    out_shardings=(p_shard, o_shard, None)
                ).lower(pspecs, ospecs, batch).compile()
            from repro.launch.dryrun import _cost_dict
            flops[sp] = float(_cost_dict(compiled).get("flops", 0))
        print(json.dumps({"flops": flops[False], "flops_sp": flops[True]}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=420,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["flops_sp"] > 0  # SP rule table lowers too
