import os

# Keep tests on the single real CPU device (the dry-run sets its own
# device-count flag in its subprocess). Cap compilation parallelism for
# the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
