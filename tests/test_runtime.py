"""Runtime tests: checkpoint manager, data pipeline, optimizer, gradient
compression, train driver loss decrease."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenStream
from repro.distributed import compression as comp
from repro.optim.adamw import AdamW, cosine_schedule


# ----------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16)},
    }
    mgr = CheckpointManager(tmp_path, keep_n=2)
    mgr.save(3, tree, {"note": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step, meta = mgr.restore(like)
    assert step == 3 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray(s)})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, {"x": jnp.ones((128, 128))}, blocking=False)
    mgr.wait()
    restored, step, _ = mgr.restore({"x": jnp.zeros((128, 128))})
    assert step == 7
    assert float(restored["x"].sum()) == 128 * 128


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros((5,))})


# ------------------------------------------------------------- pipeline --
def test_token_stream_deterministic_and_sharded():
    s = TokenStream(vocab_size=128, seq_len=32, global_batch=8, seed=1)
    b1, b2 = s.batch(5), s.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(s.batch(6)["tokens"]),
                              np.asarray(b1["tokens"]))
    h0 = s.shard_for_host(b1, 0, 2)
    h1 = s.shard_for_host(b1, 1, 2)
    recon = np.concatenate([h0["tokens"], h1["tokens"]], axis=0)
    np.testing.assert_array_equal(recon, np.asarray(b1["tokens"]))


def test_labels_shifted():
    s = TokenStream(vocab_size=128, seq_len=16, global_batch=2, seed=0)
    b = s.batch(0)
    np.testing.assert_array_equal(
        np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
    )
    assert np.all(np.asarray(b["labels"][:, -1]) == -1)


# ------------------------------------------------------------ optimizer --
def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_clipping_and_schedule():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) <= 0.2
    opt = AdamW(lr=0.1, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(metrics["grad_norm"]) > 100


# ---------------------------------------------------------- compression --
def test_quantize_roundtrip_small_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = comp.quantize(x)
    err = np.abs(np.asarray(comp.dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.51 + 1e-9


def test_error_feedback_unbiased_over_time():
    """Sum of EF-compressed grads converges to sum of raw grads."""
    key = jax.random.PRNGKey(1)
    grads_seq = [jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.1
                 for i in range(50)]
    state = comp.init_ef_state({"g": grads_seq[0]})
    total_comp = jnp.zeros(64)
    for g in grads_seq:
        qtree, state = comp.ef_compress_tree({"g": g}, state)
        total_comp = total_comp + comp.dequantize(*qtree["g"])
    total_raw = sum(grads_seq)
    # residual bounds the gap: |sum_comp - sum_raw| == |residual|
    gap = np.abs(np.asarray(total_comp - total_raw))
    res = np.abs(np.asarray(state.residual["g"]))
    np.testing.assert_allclose(gap, res, atol=1e-5)
    assert gap.max() < 0.01  # one quantization step, not 50


def test_compressed_training_still_converges():
    """AdamW on a quadratic with int8 EF gradients reaches the optimum."""
    opt = AdamW(lr=0.05, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = opt.init(params)
    ef = comp.init_ef_state(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        qtree, ef = comp.ef_compress_tree(grads, ef)
        deq = comp.ef_decompress_tree(qtree, grads)
        params, state, _ = opt.update(deq, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


# ------------------------------------------------------------ training --
@pytest.mark.slow
def test_train_loop_decreases_loss(tmp_path):
    from repro.launch.train import main as train_main

    loss_end = train_main([
        "--arch", "qwen1_5_0_5b", "--smoke", "--steps", "60",
        "--seq-len", "64", "--batch", "4", "--lr", "3e-3",
        "--warmup", "5", "--ckpt-dir", str(tmp_path / "ck"),
    ])
    # loss after 60 steps on patterned data well below ln(512)=6.24 init
    assert loss_end < 5.9


@pytest.mark.slow
def test_train_restart_resumes(tmp_path):
    from repro.launch.train import main as train_main

    ck = str(tmp_path / "ck")
    args = ["--arch", "qwen1_5_0_5b", "--smoke", "--seq-len", "32",
            "--batch", "2", "--lr", "1e-3", "--ckpt-dir", ck,
            "--ckpt-every", "10"]
    loss_full = train_main(args + ["--steps", "30"])
    # interrupted run: 30 steps in one go == 20 then resume to 30
    ck2 = str(tmp_path / "ck2")
    args2 = [a if a != ck else ck2 for a in args]
    train_main(args2 + ["--steps", "20"])
    loss_resumed = train_main(args2 + ["--steps", "30"])
    assert abs(loss_full - loss_resumed) < 1e-4


@pytest.mark.slow
def test_compressed_psum_multidevice_subprocess():
    """compressed_psum_grads inside shard_map on 8 fake devices: the
    summed gradient matches the uncompressed psum within int8 tolerance."""
    import json as _json
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compression as comp

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.1

        def body(g_blk):
            grads = {"w": g_blk[0]}
            state = comp.init_ef_state(grads)
            summed, state = comp.compressed_psum_grads(grads, state, "data")
            return summed["w"]

        from repro.distributed.compat import shard_map
        fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                       out_specs=P(), check_vma=False)
        got = fn(g)
        want = jnp.sum(g, axis=0)
        err = float(jnp.max(jnp.abs(got - want)))
        scale = float(jnp.max(jnp.abs(g))) / 127 * 8
        print(json.dumps({"err": err, "tol": scale}))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = _json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] <= rec["tol"] + 1e-6, rec


@pytest.mark.slow
def test_elastic_restart_on_fewer_devices():
    """Checkpoints are layout-free: a run sharded over 8 devices restores
    and continues on 4 (elastic scale-down after pod loss)."""
    import json as _json
    import subprocess
    import sys
    import tempfile
    import textwrap

    tmp = tempfile.mkdtemp()
    code_tpl = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import jax, json
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs import registry
        from repro.data.pipeline import make_batch_fn
        from repro.models import build_model
        from repro.optim.adamw import AdamW, make_train_step

        mesh = jax.make_mesh(({n},), ("data",))
        cfg = registry.get_smoke_config("qwen1_5_0_5b")
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        step_fn = jax.jit(make_train_step(model, opt))
        batch_fn = make_batch_fn(cfg, 32, 8)
        mgr = CheckpointManager("{tmp}")
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        start = 0
        if mgr.latest_step() is not None:
            tree, start, _ = mgr.restore(
                {{"params": params, "opt": opt_state}})
            params, opt_state = tree["params"], tree["opt"]
        # shard the batch over however many devices exist now
        shard = NamedSharding(mesh, P("data"))
        for s in range(start, start + 5):
            batch = jax.tree.map(
                lambda x: jax.device_put(x, shard), batch_fn(s))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        mgr.save(start + 5, {{"params": params, "opt": opt_state}})
        print(json.dumps({{"loss": float(metrics["loss"]),
                           "devices": {n}, "end": start + 5}}))
    """
    outs = []
    for n in (8, 4):  # scale DOWN mid-run
        code = textwrap.dedent(code_tpl.format(n=n, tmp=tmp))
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": "/root", "JAX_PLATFORMS": "cpu"},
            cwd="/root/repo", timeout=420,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        outs.append(_json.loads(out.stdout.strip().splitlines()[-1]))
    assert outs[0]["end"] == 5 and outs[1]["end"] == 10
    assert np.isfinite(outs[1]["loss"])
    # training continued productively after the elastic restart
    assert outs[1]["loss"] < 6.5
