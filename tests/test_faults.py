"""Fault-injection layer tests: the zero-fault bitwise anchor (plain /
WAN / fleet, both score backends), guard-equals-inner parity, outage
service masking, telemetry staleness, hard link flaps on infinite-
bandwidth links, task-failure conservation, and the StalenessGuard
degradation semantics (V decay + outage-aware dispatch) probed with
hand-built FaultViews."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import fleet_scenarios
from repro.configs.fleet_scenarios import (
    FAULT_SCENARIOS,
    build_fleet,
    build_network_fleet,
    with_faults,
)
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
    simulate_fleet,
)
from repro.faults import (
    FaultView,
    StalenessGuardPolicy,
    make_faults,
    no_faults,
)
from repro.network import NetworkAwareDPPPolicy, direct_graph, star_graph

jax.config.update("jax_enable_x64", False)

T = 48
M, N = 4, 3


def _setup():
    spec = fleet_scenarios._base(M, N)
    return (
        spec,
        RandomCarbonSource(N=N),
        UniformArrivals(M=M),
        jax.random.PRNGKey(42),
    )


def _assert_common_fields_equal(ref, faulted):
    """Every field the fault-free result also has must match bitwise."""
    for name in type(ref)._fields:
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(faulted, name))
        np.testing.assert_array_equal(a, b, err_msg=name)


# ---------------------------------------------------- zero-fault anchor


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_zero_fault_bitwise_parity_plain(backend):
    """faults=no_faults() reproduces the fault-free simulator
    bit-for-bit: every mask is an exact 1.0/0.0 and the fault PRNG
    stream is salted off the main key, so the arithmetic reduces to
    identities."""
    spec, src, arr, key = _setup()
    interp = True if backend == "pallas" else None
    pol = CarbonIntensityPolicy(
        V=0.05, score_backend=backend, score_interpret=interp
    )
    r0 = simulate(pol, spec, src, arr, T, key)
    r1 = simulate(pol, spec, src, arr, T, key, faults=no_faults(N))
    _assert_common_fields_equal(r0, r1)
    assert float(jnp.sum(r1.failed)) == 0.0
    assert float(jnp.sum(r1.stale)) == 0.0
    assert float(jnp.sum(r1.wasted)) == 0.0


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_zero_fault_bitwise_parity_network(backend):
    spec, src, arr, key = _setup()
    g = star_graph(M, N, np.random.default_rng(7))
    interp = True if backend == "pallas" else None
    pol = NetworkAwareDPPPolicy(
        V=0.05, score_backend=backend, score_interpret=interp
    )
    r0 = simulate(pol, spec, src, arr, T, key, graph=g)
    r1 = simulate(
        pol, spec, src, arr, T, key, graph=g,
        faults=no_faults(N, g.L),
    )
    _assert_common_fields_equal(r0, r1)
    assert float(jnp.sum(r1.links_down)) == 0.0


def test_zero_fault_guard_is_inner_bitwise():
    """Fresh signal + no outage: the guard's adjustments are exact
    identities (V * 1.0, Qc + 0.0), so guard(inner) == inner."""
    spec, src, arr, key = _setup()
    inner = CarbonIntensityPolicy(V=0.05)
    fp = no_faults(N)
    r0 = simulate(inner, spec, src, arr, T, key, faults=fp)
    r1 = simulate(
        StalenessGuardPolicy(inner=inner), spec, src, arr, T, key,
        faults=fp,
    )
    _assert_common_fields_equal(r0, r1)


def test_zero_fault_fleet_parity():
    """A fleet with all-zero-rate faults matches the fault-free fleet on
    every shared field -- simulate_fleet sweeps fault scenarios across
    lanes in the same compiled call."""
    from repro.faults.model import stack_faults

    fleet = build_fleet(
        ["diurnal-slack"], per_kind=2, M=M, N=N, Tc=24, seed=0
    )
    zeros = fleet._replace(
        faults=stack_faults([no_faults(N)] * fleet.arrival_amax.shape[0])
    )
    pol = CarbonIntensityPolicy(V=0.05)
    key = jax.random.PRNGKey(3)
    r0 = simulate_fleet(pol, fleet, T, key)
    r1 = simulate_fleet(pol, zeros, T, key)
    _assert_common_fields_equal(r0, r1)


# ------------------------------------------------------- fault dynamics


def test_scheduled_blackout_masks_service():
    """During the scheduled window cloud 0 spends zero energy no matter
    what the policy wants, and the down-cloud count reflects it."""
    spec, src, arr, key = _setup()
    fp = make_faults(
        N,
        sched_start=np.array([5.0, 1e9, 1e9], np.float32),
        sched_len=np.array([10.0, 0.0, 0.0], np.float32),
    )
    r = simulate(QueueLengthPolicy(), spec, src, arr, T, key, faults=fp)
    ec = np.asarray(r.energy_cloud)
    assert np.all(ec[5:15, 0] == 0.0)
    down = np.asarray(r.clouds_down)
    assert np.all(down[5:15] >= 1.0)
    assert np.all(down[:5] == 0.0) and np.all(down[15:] == 0.0)


def test_telemetry_dropout_freezes_view():
    """A permanently-down feed: staleness counts 1..T and the policy
    sees the frozen (initial) row while emissions stay on true
    intensities (nonzero with work flowing)."""
    spec, src, arr, key = _setup()
    fp = make_faults(N, telem_p_down=1.0, telem_p_up=0.0)
    r = simulate(
        CarbonIntensityPolicy(V=0.05), spec, src, arr, T, key, faults=fp
    )
    np.testing.assert_array_equal(
        np.asarray(r.stale), np.arange(1, T + 1, dtype=np.float32)
    )
    assert float(jnp.sum(r.emissions)) > 0.0


def test_hard_link_flap_no_nan_nothing_delivered():
    """link_floor=0 on an infinite-bandwidth direct graph: the
    inf * 0 hazard in the drain ratio must be guarded -- no NaNs, zero
    deliveries, all links down."""
    spec, src, arr, key = _setup()
    g = direct_graph(M, N)
    fp = make_faults(
        N, g.L, link_p_down=1.0, link_p_up=0.0, link_floor=0.0
    )
    r = simulate(
        NetworkAwareDPPPolicy(V=0.05), spec, src, arr, T, key,
        graph=g, faults=fp,
    )
    for name in type(r)._fields:
        leaf = getattr(r, name)
        if leaf is None:  # telemetry/deadlines off by default
            continue
        assert not np.any(np.isnan(np.asarray(leaf))), name
    assert float(jnp.sum(r.delivered)) == 0.0
    np.testing.assert_array_equal(
        np.asarray(r.links_down), np.full(T, g.L, np.float32)
    )


def test_total_task_failure_conservation():
    """task_p_fail=1: every processing attempt fails (integral counts
    make the stochastic rounding exact), wasted carbon accrues, and the
    ledger balances exactly:
    backlog = cum(arrived) - cum(processed) + cum(failed)."""
    spec, src, arr, key = _setup()
    fp = make_faults(N, task_p_fail=1.0)
    r = simulate(
        QueueLengthPolicy(), spec, src, arr, T, key, faults=fp
    )
    np.testing.assert_array_equal(
        np.asarray(r.failed), np.asarray(r.processed)
    )
    assert float(jnp.sum(r.processed)) > 0.0
    assert float(jnp.sum(r.wasted)) > 0.0
    lhs = np.asarray(r.backlog)
    rhs = (
        np.cumsum(np.asarray(r.arrived))
        - np.cumsum(np.asarray(r.processed))
        + np.cumsum(np.asarray(r.failed))
    )
    np.testing.assert_array_equal(lhs, rhs)


def test_retry_pool_releases_after_recovery():
    """Failures during an early blackout re-enter the system once the
    cloud is back: requeued > 0 and the run ends with work completed
    (processed > failed overall)."""
    spec, src, arr, key = _setup()
    fp = make_faults(
        N,
        task_p_fail=np.array([0.5, 0.0, 0.0], np.float32),
        sched_start=np.array([10.0, 1e9, 1e9], np.float32),
        sched_len=np.array([6.0, 0.0, 0.0], np.float32),
    )
    r = simulate(
        QueueLengthPolicy(), spec, src, arr, 96, key, faults=fp
    )
    assert float(jnp.sum(r.requeued)) > 0.0
    assert float(jnp.sum(r.processed)) > float(jnp.sum(r.failed))


# ------------------------------------------------- guard degradation


def _fresh_view(stale=0, cloud_on=None):
    return FaultView(
        obs_row=jnp.zeros((N + 1,), jnp.float32),
        stale=jnp.asarray(stale, jnp.int32),
        cloud_cap=jnp.ones((N,), jnp.float32)
        if cloud_on is None else jnp.asarray(cloud_on, jnp.float32),
        cloud_on=jnp.ones((N,), jnp.float32)
        if cloud_on is None else jnp.asarray(cloud_on, jnp.float32),
        released=jnp.zeros((M, N), jnp.float32),
    )


def test_guard_fully_stale_equals_v_zero(rng):
    """At stale >= stale_after the guard's effective V is exactly 0 --
    actions match the inner policy with V=0 (pure backpressure)."""
    from repro.core.queueing import NetworkState

    spec = fleet_scenarios._base(M, N)
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(1, 50, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 50, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(300.0)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    a = jnp.zeros((M,), jnp.float32)
    inner = CarbonIntensityPolicy(V=0.05)
    guard = StalenessGuardPolicy(inner=inner, stale_after=8)
    act_g = guard(state, spec, Ce, Cc, a, fault_view=_fresh_view(stale=8))
    act_0 = dataclasses.replace(inner, V=0.0)(state, spec, Ce, Cc, a)
    np.testing.assert_array_equal(np.asarray(act_g.d), np.asarray(act_0.d))
    np.testing.assert_array_equal(np.asarray(act_g.w), np.asarray(act_0.w))


def test_guard_outage_aware_dispatch_avoids_down_cloud(rng):
    """Cloud 0 down: the guard's virtual backlog prices it out of the
    argmin, so no dispatch targets it even when it is the carbon-
    cheapest target; the unguarded inner policy does dispatch to it."""
    from repro.core.queueing import NetworkState

    spec = fleet_scenarios._base(M, N)
    state = NetworkState(
        Qe=jnp.full((M,), 200.0, jnp.float32),
        Qc=jnp.zeros((M, N), jnp.float32),
    )
    Ce = jnp.float32(600.0)
    Cc = jnp.asarray([1.0, 500.0, 500.0], jnp.float32)  # cloud 0 cheapest
    a = jnp.zeros((M,), jnp.float32)
    inner = CarbonIntensityPolicy(V=0.05)
    view = _fresh_view(cloud_on=[0.0, 1.0, 1.0])
    act_g = StalenessGuardPolicy(inner=inner)(
        state, spec, Ce, Cc, a, fault_view=view
    )
    act_i = inner(state, spec, Ce, Cc, a)
    assert float(jnp.sum(act_g.d[:, 0])) == 0.0
    assert float(jnp.sum(act_i.d[:, 0])) > 0.0
    assert float(jnp.sum(act_g.d)) > 0.0  # still dispatches elsewhere


def test_guard_all_down_stops_dispatch():
    from repro.core.queueing import NetworkState

    spec = fleet_scenarios._base(M, N)
    state = NetworkState(
        Qe=jnp.full((M,), 200.0, jnp.float32),
        Qc=jnp.zeros((M, N), jnp.float32),
    )
    act = StalenessGuardPolicy(inner=CarbonIntensityPolicy(V=0.05))(
        state, spec, jnp.float32(1.0),
        jnp.asarray([1.0, 1.0, 1.0], jnp.float32),
        jnp.zeros((M,), jnp.float32),
        fault_view=_fresh_view(cloud_on=[0.0, 0.0, 0.0]),
    )
    assert float(jnp.sum(act.d)) == 0.0


# ------------------------------------------------- constructors/config


def test_make_faults_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown FaultParams"):
        make_faults(N, typo_rate=0.1)


def test_make_faults_rejects_link_fields_without_L():
    with pytest.raises(ValueError, match="need L"):
        make_faults(N, link_p_down=0.1)


def test_guard_validates_construction():
    with pytest.raises(ValueError, match="stale_after"):
        StalenessGuardPolicy(inner=CarbonIntensityPolicy(), stale_after=0)
    with pytest.raises(ValueError, match="V field"):
        StalenessGuardPolicy(inner=object())


def test_fault_scenarios_registry_builds():
    """Every registered scenario attaches per-lane stacked FaultParams
    to its fleet; flappy-uplink demands a WAN fleet."""
    fleet = build_fleet(
        ["diurnal-slack"], per_kind=2, M=M, N=N, Tc=24, seed=0
    )
    for kind in ("regional-blackout", "telemetry-brownout"):
        assert kind in FAULT_SCENARIOS
        f = with_faults(fleet, kind)
        assert f.faults is not None
        assert f.faults.cloud_p_down.shape == (2, N)
    wan = build_network_fleet(
        ["congested-uplink"], per_kind=2, M=M, N=N, Tc=24, seed=0
    )
    fw = with_faults(wan, "flappy-uplink")
    assert fw.faults.link_p_down.shape[0] == 2
    with pytest.raises(ValueError):
        with_faults(fleet, "flappy-uplink")  # no graph -> no links
