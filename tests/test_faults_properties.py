"""Property-based fault-layer invariants (skipped cleanly when
`hypothesis` is absent from the environment):

* task conservation under ARBITRARY fault streams -- every slot,
  cum(arrived) = Qe + Qc + retry + cum(processed) - cum(failed),
  exact in float32 because every term is an integral count;
* record="summary" scalar series are bitwise-equal to record="full"
  under faults (both modes run the same scan body).
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import fleet_scenarios  # noqa: E402
from repro.core import (  # noqa: E402
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
)
from repro.faults import StalenessGuardPolicy, make_faults  # noqa: E402

jax.config.update("jax_enable_x64", False)

T = 32
M, N = 3, 2

rate = st.floats(0.0, 1.0, allow_nan=False, width=32)


@st.composite
def fault_params(draw):
    return make_faults(
        N,
        cloud_p_down=draw(st.floats(0.0, 0.5, width=32)),
        cloud_p_up=draw(rate),
        brown_p_start=draw(rate),
        brown_p_end=draw(rate),
        brown_floor=draw(st.floats(0.1, 1.0, width=32)),
        task_p_fail=draw(rate),
        telem_p_down=draw(rate),
        telem_p_up=draw(rate),
        backoff_max=float(draw(st.integers(0, 8))),
    )


def _run(fp, seed, policy=None, record="full"):
    spec = fleet_scenarios._base(M, N)
    return simulate(
        policy or QueueLengthPolicy(), spec,
        RandomCarbonSource(N=N), UniformArrivals(M=M),
        T, jax.random.PRNGKey(seed), faults=fp, record=record,
    )


@settings(max_examples=15, deadline=None)
@given(fp=fault_params(), seed=st.integers(0, 2**31 - 1))
def test_task_conservation_any_fault_stream(fp, seed):
    """No fault mix creates or destroys tasks: the running backlog
    equals arrivals minus completed work, exactly."""
    r = _run(fp, seed)
    lhs = np.asarray(r.backlog)
    rhs = (
        np.cumsum(np.asarray(r.arrived))
        - np.cumsum(np.asarray(r.processed))
        + np.cumsum(np.asarray(r.failed))
    )
    np.testing.assert_array_equal(lhs, rhs)
    # the recorded queues must re-sum to the same backlog at the end
    final = (
        float(r.Qe[-1].sum()) + float(r.Qc[-1].sum())
        + float(r.retry[-1].sum())
    )
    assert final == float(lhs[-1])
    # and nothing goes negative or NaN under any stream
    for name in ("Qe", "Qc", "retry", "backlog"):
        v = np.asarray(getattr(r, name))
        assert np.all(v >= 0.0), name
        assert not np.any(np.isnan(v)), name


@settings(max_examples=8, deadline=None)
@given(fp=fault_params(), seed=st.integers(0, 2**31 - 1))
def test_summary_record_scalars_bitwise_equal_full(fp, seed):
    """record="summary" shares the scan body with record="full", so
    every scalar series is bitwise identical; only queue recording
    density differs."""
    guard = StalenessGuardPolicy(inner=CarbonIntensityPolicy(V=0.05))
    full = _run(fp, seed, policy=guard, record="full")
    summ = _run(fp, seed, policy=guard, record="summary")
    for name in type(full)._fields:
        if name in ("Qe", "Qc", "retry"):
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(full, name)),
            np.asarray(getattr(summ, name)), err_msg=name,
        )
    assert summ.Qe.shape[0] == 1
    np.testing.assert_array_equal(
        np.asarray(full.Qe[-1]), np.asarray(summ.Qe[-1])
    )
    np.testing.assert_array_equal(
        np.asarray(full.retry[-1]), np.asarray(summ.retry[-1])
    )
