"""Seeded violation: unused-import (module-level, never referenced)."""
import os

ANSWER = 42
