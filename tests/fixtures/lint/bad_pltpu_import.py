"""Seeded violation: pltpu-import (bypasses kernels/compat.py)."""
import jax.experimental.pallas.tpu as pltpu

VMEM = pltpu.VMEM
