"""Seeded violation: host-cast (float() on a traced jnp expression)."""
import jax.numpy as jnp


def traced_mean(x):
    return float(jnp.mean(x))
