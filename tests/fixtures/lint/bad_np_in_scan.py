"""Seeded violation: np-in-scan (numpy inside a lax.scan body)."""
import jax.numpy as jnp
import numpy as np
from jax import lax


def drift(xs):
    def body(carry, x):
        return carry + np.float64(0.5) * x, carry

    return lax.scan(body, jnp.float32(0), xs)
