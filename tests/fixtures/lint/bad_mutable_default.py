"""Seeded violation: mutable-default (shared across calls)."""


def accumulate(x, acc=[]):
    acc.append(x)
    return acc
