"""Seeded violation: jnp-for (Python loop over a jnp expression)."""
import jax.numpy as jnp


def unrolled_sum(n):
    total = jnp.float32(0)
    for v in jnp.arange(n):
        total = total + v
    return total
