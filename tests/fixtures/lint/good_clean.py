"""Clean fixture: every rule stays quiet, including a suppressed line."""
import os


def tmpdir(base=None):
    return base or os.environ.get("TMPDIR", "/tmp")


def allowed(x, acc=[]):  # lint: allow=mutable-default
    return acc + [x]
