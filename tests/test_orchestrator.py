"""GreenOrchestrator integration tests: real training under the paper's
scheduler, fault tolerance, straggler mitigation, elasticity."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.carbon import ConstantCarbonSource, UKRegionalTraceSource
from repro.core.policies import CarbonIntensityPolicy, QueueLengthPolicy
from repro.core.queueing import NetworkSpec
from repro.data.pipeline import make_batch_fn
from repro.models import build_model
from repro.optim.adamw import AdamW, make_train_step
from repro.orchestrator.green import Cloud, GreenOrchestrator, TrainJob


def make_jobs(names=("qwen1_5_0_5b", "internlm2_20b")):
    jobs = []
    for i, aid in enumerate(names):
        cfg = registry.get_smoke_config(aid)
        model = build_model(cfg)
        opt = AdamW(lr=1e-3)
        params = model.init(jax.random.PRNGKey(i))
        jobs.append(TrainJob(
            name=aid,
            model=model,
            train_step=jax.jit(make_train_step(model, opt)),
            batch_fn=make_batch_fn(cfg, 32, 2, seed=i),
            params=params,
            opt_state=opt.init(params),
            steps_per_task=1,
        ))
    return jobs


def make_spec(M=2, N=2):
    return NetworkSpec(
        pe=np.full(M, 1.0, np.float32),
        pc=np.full((M, N), 5.0, np.float32),
        Pe=float(4 * M),
        Pc=np.full(N, 20.0, np.float32),
    )


def arrivals(t):
    rng = np.random.default_rng((7, t))
    return rng.integers(0, 3, 2).astype(np.float32)


@pytest.fixture(scope="module")
def base_run(tmp_path_factory):
    jobs = make_jobs()
    orch = GreenOrchestrator(
        jobs=jobs,
        clouds=[Cloud("c0"), Cloud("c1")],
        spec=make_spec(),
        carbon_source=ConstantCarbonSource(N=2, Ce=10.0, Cc=10.0),
        arrival_fn=arrivals,
        policy=CarbonIntensityPolicy(V=0.01),
        ckpt_dir=str(tmp_path_factory.mktemp("ck")),
        ckpt_every=3,
        max_tasks_per_slot=3,
    )
    history = orch.run(8)
    return orch, history


def test_orchestrator_executes_and_accounts(base_run):
    orch, history = base_run
    assert orch.executed_tasks > 0
    assert orch.cum_emissions > 0
    # emissions trace is monotone nondecreasing
    trace = np.asarray(orch.cum_emissions_trace)
    assert np.all(np.diff(trace) >= 0)
    # jobs actually trained
    assert all(j.step > 0 for j in orch.jobs)


def test_orchestrator_trains_models(base_run):
    orch, _ = base_run
    for j in orch.jobs:
        assert np.isfinite(j.losses).all()


def test_checkpoint_restart_bit_exact(tmp_path):
    carbon = ConstantCarbonSource(N=2, Ce=10.0, Cc=10.0)

    def fresh(ckdir):
        return GreenOrchestrator(
            jobs=make_jobs(), clouds=[Cloud("c0"), Cloud("c1")],
            spec=make_spec(), carbon_source=carbon, arrival_fn=arrivals,
            policy=CarbonIntensityPolicy(V=0.01),
            ckpt_dir=ckdir, ckpt_every=2, max_tasks_per_slot=3,
        )

    # uninterrupted 6 slots
    a = fresh(str(tmp_path / "a"))
    a.run(6)
    # interrupted after 4 (last ckpt at t=4), new process resumes
    b1 = fresh(str(tmp_path / "b"))
    b1.run(4)
    b1.ckpt.wait()
    b2 = fresh(str(tmp_path / "b"))
    assert b2.resume()
    assert b2.t == 4
    b2.run(2)
    assert b2.t == a.t
    np.testing.assert_allclose(b2.cum_emissions, a.cum_emissions, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(b2.state.Qe),
                                  np.asarray(a.state.Qe))
    for ja, jb in zip(a.jobs, b2.jobs):
        assert ja.step == jb.step
        la = jax.tree.leaves(ja.params)
        lb = jax.tree.leaves(jb.params)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_cloud_failure_reroutes_work(tmp_path):
    orch = GreenOrchestrator(
        jobs=make_jobs(), clouds=[Cloud("c0"), Cloud("c1")],
        spec=make_spec(), carbon_source=ConstantCarbonSource(N=2, Ce=1.0,
                                                             Cc=1.0),
        arrival_fn=arrivals, policy=CarbonIntensityPolicy(V=0.001),
        max_tasks_per_slot=3,
    )
    orch.run(3, fail_at={1: 1})  # cloud 1 dies at slot 1
    assert not orch.clouds[1].alive
    # system keeps executing on the surviving cloud
    executed_before = orch.executed_tasks
    orch.run(3)
    assert orch.executed_tasks > executed_before
    # rejoin restores capacity
    orch.join_cloud(1)
    eff = orch._effective_spec()
    assert float(np.asarray(eff.Pc)[1]) > 0


def test_dead_cloud_gets_zero_capacity():
    orch = GreenOrchestrator(
        jobs=make_jobs(), clouds=[Cloud("c0"), Cloud("c1", alive=False)],
        spec=make_spec(), carbon_source=ConstantCarbonSource(N=2),
        arrival_fn=arrivals,
    )
    eff = orch._effective_spec()
    assert float(np.asarray(eff.Pc)[1]) == 0.0


def test_slowdown_denominator_scales_with_expected_tasks():
    """Regression: the estimator divided by min(expected, 1), so any
    cloud running >1 task per slot looked pathologically slow and had
    its Pc budget wrongly shrunk. The denominator must scale with the
    expected task count."""
    # 4 task-equivalents finishing in 2s against a 1s/task deadline is
    # *ahead* of schedule (ratio 0.5), not 2x slow.
    assert GreenOrchestrator._slowdown(2.0, 1.0, 4.0) == pytest.approx(0.5)
    # a genuinely slow cloud is still flagged
    assert GreenOrchestrator._slowdown(8.0, 1.0, 4.0) == pytest.approx(2.0)
    # near-idle slots clamp the denominator at one expected task
    assert GreenOrchestrator._slowdown(0.5, 1.0, 0.25) == pytest.approx(0.5)


def test_busy_on_time_cloud_not_marked_straggler():
    """A cloud that executes several tasks well within the slot deadline
    keeps measured_slowdown ~1 and full effective capacity."""
    orch = GreenOrchestrator(
        jobs=make_jobs(), clouds=[Cloud("c0"), Cloud("c1")],
        spec=make_spec(), carbon_source=ConstantCarbonSource(N=2),
        arrival_fn=arrivals, policy=CarbonIntensityPolicy(V=0.001),
        max_tasks_per_slot=3, slot_deadline_s=120.0,
    )
    orch.run(4)
    assert orch.executed_tasks > 0
    for cloud in orch.clouds:
        assert cloud.measured_slowdown == pytest.approx(1.0, abs=1e-6)
    eff = orch._effective_spec()
    np.testing.assert_allclose(
        np.asarray(eff.Pc), np.asarray(orch.spec.Pc)
    )


def test_straggler_capacity_shrinks():
    orch = GreenOrchestrator(
        jobs=make_jobs(), clouds=[Cloud("c0"), Cloud("c1")],
        spec=make_spec(), carbon_source=ConstantCarbonSource(N=2),
        arrival_fn=arrivals,
    )
    orch.clouds[0].measured_slowdown = 2.0
    eff = orch._effective_spec()
    assert float(np.asarray(eff.Pc)[0]) == pytest.approx(
        float(np.asarray(orch.spec.Pc)[0]) / 2.0
    )


def test_carbon_aware_beats_queue_policy_in_orchestrator():
    """End-to-end: with time-varying carbon the paper's policy emits less
    than the queue-length baseline for the same executed work."""
    carbon = UKRegionalTraceSource(N=2)

    def run(policy):
        orch = GreenOrchestrator(
            jobs=make_jobs(), clouds=[Cloud("c0"), Cloud("c1")],
            spec=make_spec(), carbon_source=carbon, arrival_fn=arrivals,
            policy=policy, max_tasks_per_slot=2,
        )
        orch.run(12)
        return orch

    a = run(CarbonIntensityPolicy(V=0.5))
    b = run(QueueLengthPolicy())
    # emissions per executed task lower under the carbon policy
    ea = a.cum_emissions / max(a.executed_tasks, 1)
    eb = b.cum_emissions / max(b.executed_tasks, 1)
    assert ea < eb
