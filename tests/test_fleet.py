"""Fleet-simulation engine: simulate_fleet == per-instance simulate,
scenario registry shapes, and the one-compiled-call acceptance check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fleet_scenarios import SCENARIOS, build_fleet
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    simulate,
    simulate_fleet,
)
from repro.core.queueing import NetworkSpec


def test_fleet_matches_per_instance_simulate():
    """Each lane of the vmapped fleet reproduces a standalone simulate()
    run with the same spec/table/arrivals/key."""
    fleet = build_fleet(["diurnal", "heterogeneous-fleet"], per_kind=2,
                        Tc=48, seed=3)
    T = 25
    key = jax.random.PRNGKey(7)
    pol = CarbonIntensityPolicy(V=0.05)
    res = simulate_fleet(pol, fleet, T, key)
    keys = jax.random.split(key, fleet.F)
    M = fleet.arrival_amax.shape[1]
    for f in range(fleet.F):
        spec = NetworkSpec(
            pe=fleet.spec.pe[f], pc=fleet.spec.pc[f],
            Pe=fleet.spec.Pe[f], Pc=fleet.spec.Pc[f],
        )
        ctab = fleet.carbon[f]
        amax = fleet.arrival_amax[f]

        def carbon_source(t, kk, ctab=ctab):
            del kk
            row = ctab[t % ctab.shape[0]]
            return row[0], row[1:]

        def arrival_source(t, kk, amax=amax):
            u = jax.random.uniform(jax.random.fold_in(kk, t), (M,))
            return jnp.floor(u * (amax + 1.0))

        one = simulate(pol, spec, carbon_source, arrival_source, T, keys[f])
        np.testing.assert_allclose(
            np.asarray(res.cum_emissions[f]), np.asarray(one.cum_emissions),
            rtol=1e-6,
        )
        np.testing.assert_array_equal(
            np.asarray(res.Qe[f]), np.asarray(one.Qe)
        )


def test_fleet_64_instances_one_jitted_call():
    """Acceptance: >= 64 scenario instances sweep in ONE jitted call."""
    fleet = build_fleet(per_kind=16)  # 4 registered kinds x 16 = 64
    assert fleet.F >= 64
    T = 20
    f = jax.jit(lambda k: simulate_fleet(
        CarbonIntensityPolicy(V=0.05), fleet, T, k
    ))
    res = f(jax.random.PRNGKey(0))
    assert res.cum_emissions.shape == (fleet.F, T)
    assert res.Qe.shape == (fleet.F, T, fleet.arrival_amax.shape[1])
    assert bool(jnp.isfinite(res.cum_emissions).all())
    # per-instance cumulative emissions are nondecreasing
    assert bool((jnp.diff(res.cum_emissions, axis=1) >= -1e-3).all())
    # distinct scenarios produce distinct trajectories
    assert len(np.unique(np.asarray(res.cum_emissions[:, -1]))) > 1


def test_registry_names_and_shapes():
    assert set(SCENARIOS) == {
        "diurnal", "diurnal-slack", "bursty", "heterogeneous-fleet",
        "multi-region-uk", "overload",
    }
    fleet = build_fleet(["bursty", "multi-region-uk"], per_kind=3,
                        M=7, N=4, Tc=30, seed=1)
    assert fleet.F == 6
    assert fleet.spec.pe.shape == (6, 7)
    assert fleet.spec.pc.shape == (6, 7, 4)
    assert fleet.spec.Pc.shape == (6, 4)
    assert fleet.carbon.shape == (6, 30, 5)
    assert fleet.arrival_amax.shape == (6, 7)
    # tables are valid intensities
    assert float(fleet.carbon.min()) >= 0.0
    assert float(fleet.carbon.max()) <= 700.0


def test_build_fleet_unknown_name():
    with pytest.raises(KeyError, match="registered"):
        build_fleet(["no-such-scenario"], per_kind=1)


def test_fleet_record_summary_matches_full():
    """One compiled call, F lanes, record="summary": scalar series
    bitwise equal to full recording, Qe/Qc collapse to [F, 1, ...]."""
    fleet = build_fleet(["diurnal", "bursty"], per_kind=3, Tc=48, seed=5)
    T, key = 40, jax.random.PRNGKey(11)
    pol = CarbonIntensityPolicy(V=0.05)
    full = simulate_fleet(pol, fleet, T, key)
    summ = jax.jit(lambda k: simulate_fleet(
        pol, fleet, T, k, record="summary"
    ))(key)
    for name in ("emissions", "cum_emissions", "dispatched", "processed",
                 "energy_edge", "energy_cloud"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full, name)), np.asarray(getattr(summ, name)),
            err_msg=name,
        )
    M = fleet.arrival_amax.shape[1]
    assert summ.Qe.shape == (fleet.F, 1, M)
    np.testing.assert_array_equal(
        np.asarray(full.Qe[:, -1]), np.asarray(summ.Qe[:, 0])
    )
    np.testing.assert_array_equal(
        np.asarray(full.Qc[:, -1]), np.asarray(summ.Qc[:, 0])
    )


def test_fleet_carbon_policy_beats_queue_policy_on_average():
    """The paper's headline holds across a heterogeneous fleet: averaged
    over scenarios, the carbon-aware policy emits less than the
    queue-length baseline."""
    fleet = build_fleet(per_kind=4, Tc=48, seed=9)  # F=16
    T = 60
    key = jax.random.PRNGKey(2)
    carb = simulate_fleet(CarbonIntensityPolicy(V=0.05), fleet, T, key)
    base = simulate_fleet(QueueLengthPolicy(), fleet, T, key)
    mean_carb = float(carb.cum_emissions[:, -1].mean())
    mean_base = float(base.cum_emissions[:, -1].mean())
    assert mean_carb < mean_base
