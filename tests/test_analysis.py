"""Tests for the repro.analysis static-analysis layer.

Covers: every lint rule fires on its seeded fixture (and only that
rule), the repo tree lints clean, suppression comments work, the jaxpr
auditor detects seeded weak-carry / host-callback programs and passes a
representative registry combo, the retrace audit proves signature
uniqueness and catches unhashable policies, the CLI exit codes, and the
checkify lift both running clean and actually catching an injected NaN.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis.audit import (
    Combo,
    audit_combo,
    audit_jaxpr,
    iter_combos,
    retrace_audit,
)
from repro.analysis.lint import RULES, lint_file, lint_repo
from repro.analysis.sanitize import DEFAULT_CHECKS, checkified_simulate_fleet

FIXTURES = Path(__file__).parent / "fixtures" / "lint"
REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# lint rules


@pytest.mark.parametrize("rule", RULES)
def test_each_rule_fires_on_its_fixture(rule):
    path = FIXTURES / f"bad_{rule.replace('-', '_')}.py"
    violations = lint_file(path)
    assert violations, f"{path.name} produced no findings"
    assert {v.rule for v in violations} == {rule}, (
        f"{path.name} fired {[v.rule for v in violations]}, wanted {rule}"
    )


def test_clean_fixture_and_suppression():
    # good_clean.py includes a mutable default behind `# lint: allow=`;
    # zero findings proves both the rules' precision and suppression.
    assert lint_file(FIXTURES / "good_clean.py") == []


def test_repo_lints_clean():
    violations = lint_repo(REPO)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_baseline_is_empty():
    # The gate's contract: after this PR's sweep no accepted violations
    # remain, so any future finding is NEW and fails CI.
    baseline = json.loads(
        (REPO / "src/repro/analysis/baseline.json").read_text()
    )
    assert baseline == {"audit": {}, "lint": {}}


# ---------------------------------------------------------------------------
# jaxpr auditor


def test_audit_detects_weak_carry():
    def f(x):
        def body(c, _):
            return c + 1.0, ()

        # python-float carry -> float32 weak_type in the scan carry
        c, _ = lax.scan(body, 0.0, None, length=3)
        return c + x

    closed = jax.make_jaxpr(f)(jnp.float32(0))
    findings = audit_jaxpr(closed, "seeded")
    assert any(v.check == "weak-carry" for v in findings)


def test_audit_detects_host_callback():
    def f(x):
        jax.debug.print("x = {}", x)
        return x * 2

    closed = jax.make_jaxpr(f)(jnp.float32(1))
    findings = audit_jaxpr(closed, "seeded")
    assert any(v.check == "effects" for v in findings)


def test_audit_detects_float64():
    def f(x):
        return x.astype(jnp.float64) * 2

    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        closed = jax.make_jaxpr(f)(jnp.float32(1))
    finally:
        jax.config.update("jax_enable_x64", prev)
    findings = audit_jaxpr(closed, "seeded", x64_mode=True)
    assert any(v.check == "x64" for v in findings)


def test_representative_combo_audits_clean():
    combos = iter_combos(per_kind=1)
    combo = next(c for c in combos if c.name == "ci/reference@diurnal")
    findings = audit_combo(combo)
    assert findings == [], "\n".join(str(v) for v in findings)


# ---------------------------------------------------------------------------
# retrace audit


def test_retrace_audit_clean_and_unique():
    violations, report = retrace_audit()
    assert violations == [], "\n".join(str(v) for v in violations)
    # every (policy, backend) family is present and each shape class
    # carries exactly one signature (that is the report's structure)
    assert "ci/reference" in report and "aware/pallas" in report
    for classes in report.values():
        assert len(classes) >= 1


def test_retrace_audit_catches_unhashable_policy():
    fake = Combo(
        name="fake@nowhere", policy_key="fake", scenario="nowhere",
        make_policy=lambda: [],  # lists are unhashable
        forecaster=None, fleet=jnp.zeros(3), record="full",
    )
    violations, _ = retrace_audit([fake])
    assert any(v.check == "retrace" for v in violations)


# ---------------------------------------------------------------------------
# CLI


def _run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=REPO,
    )


def test_cli_nonzero_on_each_fixture():
    for rule in RULES:
        path = FIXTURES / f"bad_{rule.replace('-', '_')}.py"
        proc = _run_cli(str(path))
        assert proc.returncode == 1, (rule, proc.stdout, proc.stderr)
        assert rule in proc.stdout


def test_cli_zero_on_clean_fixture():
    proc = _run_cli(str(FIXTURES / "good_clean.py"))
    assert proc.returncode == 0, (proc.stdout, proc.stderr)


def test_cli_lint_mode_clean_on_repo():
    proc = _run_cli("--lint")
    assert proc.returncode == 0, (proc.stdout, proc.stderr)


# ---------------------------------------------------------------------------
# checkify sanitizer


def _tiny_fleet():
    from repro.configs.fleet_scenarios import build_fleet

    return build_fleet(["diurnal-slack"], per_kind=1, M=4, N=3,
                       Tc=24, seed=0)


def test_checkified_fleet_runs_clean():
    from repro.core.policies import CarbonIntensityPolicy

    err, res = checkified_simulate_fleet(
        CarbonIntensityPolicy(), _tiny_fleet(), 6, jax.random.PRNGKey(0)
    )
    assert err.get() is None
    assert res.emissions.dtype == jnp.float32


def test_checkified_fleet_catches_injected_nan():
    from repro.core.policies import CarbonIntensityPolicy

    def poison(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.full_like(x, jnp.nan)
        return x

    bad = jax.tree.map(poison, _tiny_fleet())
    err, _ = checkified_simulate_fleet(
        CarbonIntensityPolicy(), bad, 6, jax.random.PRNGKey(0)
    )
    assert err.get() is not None
    assert "nan" in err.get().lower()


def test_checkified_single_full_checks_through_while_loop():
    # fill_chunk < M forces the chunked greedy fill's while_loop; the
    # full check set (incl. OOB index checks) must discharge through it
    from jax.experimental import checkify

    from repro.configs.paper_workloads import paper_spec
    from repro.core.carbon import RandomCarbonSource
    from repro.core.policies import CarbonIntensityPolicy
    from repro.core.simulator import UniformArrivals, simulate

    spec = paper_spec()

    def run(k):
        return simulate(
            CarbonIntensityPolicy(fill_chunk=2), spec,
            RandomCarbonSource(N=spec.N), UniformArrivals(M=spec.M),
            6, k,
        )

    err, res = jax.jit(
        checkify.checkify(run, errors=DEFAULT_CHECKS)
    )(jax.random.PRNGKey(0))
    assert err.get() is None
    jax.block_until_ready(res)
