"""Parametrized dtype discipline over every recorded trajectory field.

The simulator contract (DESIGN.md, core/queueing.py DTYPE) is float32
state and int32 counters everywhere -- no float64 creep, no weak types,
no surprise promotions -- across every policy, both score backends, and
all three recording modes. The jaxpr auditor proves this abstractly for
the registry; this test proves it on concrete outputs, field by field.
"""
import jax
import pytest

from repro.configs.fleet_scenarios import build_fleet, build_network_fleet
from repro.core.policies import (
    CarbonIntensityPolicy,
    LookaheadDPPPolicy,
    QueueLengthPolicy,
    RandomPolicy,
)
from repro.core.simulator import simulate_fleet
from repro.forecast import SeasonalNaiveForecaster
from repro.network import NetworkAwareDPPPolicy, StaticRoutePolicy

T = 10
ALLOWED = {"float32", "int32"}

POLICIES = [
    ("ci/reference", lambda: CarbonIntensityPolicy(), None),
    ("ci/pallas",
     lambda: CarbonIntensityPolicy(score_backend="pallas"), None),
    ("queue-length", lambda: QueueLengthPolicy(), None),
    ("random", lambda: RandomPolicy(), None),
    ("lookahead", lambda: LookaheadDPPPolicy(H=4),
     SeasonalNaiveForecaster(H=4, period=6)),
]

WAN_POLICIES = [
    ("aware/reference", lambda: NetworkAwareDPPPolicy()),
    ("aware/pallas",
     lambda: NetworkAwareDPPPolicy(score_backend="pallas")),
    ("blind", lambda: StaticRoutePolicy(CarbonIntensityPolicy())),
]

RECORDS = ["full", "summary", 2]


@pytest.fixture(scope="module")
def fleet():
    return build_fleet(["diurnal-slack"], per_kind=1, M=4, N=3,
                       Tc=24, seed=0)


@pytest.fixture(scope="module")
def wan_fleet():
    return build_network_fleet(["star"], per_kind=1, M=4, N=3,
                               Tc=24, seed=0)


def _assert_disciplined(res, label):
    fields = getattr(res, "_fields", None)
    assert fields, f"{label}: result is not a NamedTuple"
    for field in fields:
        leaf = getattr(res, field)
        if field in ("telemetry", "deadlines"):
            # Off by default in these runs; when a frame/ledger is
            # attached its leaves obey the same discipline (recurse).
            if leaf is None:
                continue
            for path, sub in jax.tree_util.tree_flatten_with_path(leaf)[0]:
                dtype = str(sub.dtype)
                assert dtype in ALLOWED, (
                    f"{label}: {field} leaf {path} is {dtype}"
                )
            continue
        dtype = str(leaf.dtype)
        assert dtype in ALLOWED, (
            f"{label}: field {field!r} is {dtype}, not in {ALLOWED}"
        )
        assert not getattr(leaf, "weak_type", False), (
            f"{label}: field {field!r} is weak-typed"
        )


@pytest.mark.parametrize("record", RECORDS,
                         ids=[str(r) for r in RECORDS])
@pytest.mark.parametrize("name,make,forecaster", POLICIES,
                         ids=[p[0] for p in POLICIES])
def test_fleet_trajectory_dtypes(fleet, name, make, forecaster, record):
    res = simulate_fleet(make(), fleet, T, jax.random.PRNGKey(0),
                         forecaster=forecaster, record=record)
    _assert_disciplined(res, f"{name}/record={record}")


@pytest.mark.parametrize("record", RECORDS,
                         ids=[str(r) for r in RECORDS])
@pytest.mark.parametrize("name,make", WAN_POLICIES,
                         ids=[p[0] for p in WAN_POLICIES])
def test_wan_trajectory_dtypes(wan_fleet, name, make, record):
    res = simulate_fleet(make(), wan_fleet, T, jax.random.PRNGKey(0),
                         record=record)
    _assert_disciplined(res, f"{name}/record={record}")


def test_fleet_telemetry_dtypes(fleet):
    from repro.telemetry import TelemetryConfig

    res = simulate_fleet(CarbonIntensityPolicy(), fleet, T,
                         jax.random.PRNGKey(0), record="summary",
                         telemetry=TelemetryConfig())
    _assert_disciplined(res, "ci/telemetry-on")


def test_fleet_deadline_dtypes(fleet):
    from repro.configs.fleet_scenarios import with_deadlines

    res = simulate_fleet(CarbonIntensityPolicy(),
                         with_deadlines(fleet, "tight-uniform"), T,
                         jax.random.PRNGKey(0), record="summary")
    _assert_disciplined(res, "ci/deadlines-on")


def test_fleet_trajectory_dtypes_stable_under_x64(fleet):
    """The pinned dtypes hold even when tracing with x64 enabled --
    the config that used to flip the arrival draws to float64."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        closed = jax.make_jaxpr(
            lambda f, k: simulate_fleet(
                CarbonIntensityPolicy(), f, T, k, record="summary"
            )
        )(fleet, jax.random.PRNGKey(0))
    finally:
        jax.config.update("jax_enable_x64", prev)
    dtypes = {str(v.aval.dtype) for v in closed.jaxpr.outvars}
    assert "float64" not in dtypes, dtypes
