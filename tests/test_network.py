"""WAN transfer subsystem tests: degenerate-graph parity (the
regression anchor), route-kernel backend equivalence, Qt conservation,
bandwidth-cap saturation, ceil(size/bw) latency, and vmap shape/dtype
contracts across stacked topologies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fleet_scenarios import (
    NETWORK_SCENARIOS,
    build_network_fleet,
)
from repro.core import (
    CarbonIntensityPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
    simulate_fleet,
)
from repro.core.queueing import NetworkSpec, NetworkState
from repro.network import (
    NetworkAwareDPPPolicy,
    StaticRoutePolicy,
    direct_graph,
    init_links,
    make_graph,
    step_links,
)

jax.config.update("jax_enable_x64", False)


def _random_instance(rng, M, N):
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=float(rng.uniform(100, 2000)),
        Pc=rng.uniform(100, 5000, N).astype(np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    return spec, state, Ce, Cc


# ------------------------------------------------- degenerate-graph parity


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("chunk", [8, 512])
def test_degenerate_graph_policy_bit_parity(backend, chunk):
    """On direct_graph (one infinite-bandwidth, zero-transfer-carbon
    link per cloud) NetworkAwareDPPPolicy's actions are BIT-IDENTICAL
    to CarbonIntensityPolicy's on both score backends -- the
    subsystem's regression anchor."""
    rng = np.random.default_rng(7)
    for M, N in [(5, 5), (23, 9), (64, 16)]:
        spec, state, Ce, Cc = _random_instance(rng, M, N)
        g = direct_graph(M, N)
        Qt0 = jnp.zeros((M, N), jnp.float32)
        # score_interpret=True pins the pallas backend to the real
        # (emulated) kernels on CPU; the reference backend ignores it.
        interp = True if backend == "pallas" else None
        base = CarbonIntensityPolicy(
            V=0.05, fill_chunk=chunk, score_backend=backend,
            score_interpret=interp,
        )
        net = NetworkAwareDPPPolicy(
            V=0.05, fill_chunk=chunk, score_backend=backend,
            score_interpret=interp,
        )
        a = jax.jit(lambda s: base(s, spec, Ce, Cc, None, None))(state)
        b = jax.jit(
            lambda s: net(s, spec, Ce, Cc, None, None, graph=g, Qt=Qt0)
        )(state)
        np.testing.assert_array_equal(np.asarray(a.d), np.asarray(b.dt))
        np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_degenerate_graph_simulation_parity(backend):
    """Full trajectories through the WAN simulator on direct_graph
    match the link-free simulator: queue trajectories bitwise, Qt pinned
    at zero, emissions to float tolerance (the two scan bodies fuse
    reductions differently, as in test_fleet's per-instance check)."""
    rng = np.random.default_rng(3)
    M, N = 11, 6
    spec, _, _, _ = _random_instance(rng, M, N)
    carbon = RandomCarbonSource(N=N)
    arrive = UniformArrivals(M=M, amax=80)
    key = jax.random.PRNGKey(5)
    g = direct_graph(M, N)
    interp = True if backend == "pallas" else None
    r0 = simulate(
        CarbonIntensityPolicy(V=0.05, score_backend=backend,
                              score_interpret=interp),
        spec, carbon, arrive, 40, key,
    )
    r1 = simulate(
        NetworkAwareDPPPolicy(V=0.05, score_backend=backend,
                              score_interpret=interp),
        spec, carbon, arrive, 40, key, graph=g,
    )
    np.testing.assert_array_equal(np.asarray(r0.Qe), np.asarray(r1.Qe))
    np.testing.assert_array_equal(np.asarray(r0.Qc), np.asarray(r1.Qc))
    assert float(jnp.abs(r1.Qt).max()) == 0.0
    assert float(r1.energy_transfer.sum()) == 0.0
    np.testing.assert_allclose(
        np.asarray(r0.cum_emissions), np.asarray(r1.cum_emissions),
        rtol=1e-6,
    )


# ------------------------------------------------------- kernel equivalence


@pytest.mark.parametrize(
    "M,L,bm,bl",
    [
        (5, 5, 256, 256),      # tiny, blocks larger than the array
        (128, 128, 128, 128),  # exact block fit
        (100, 37, 64, 16),     # non-multiple of block in both dims
        (257, 129, 128, 128),  # one row/col past the block boundary
    ],
)
def test_route_kernel_bit_identical(M, L, bm, bl):
    from repro.kernels import ops

    rng = np.random.default_rng(M * 100 + L)
    for _ in range(3):
        Qt = jnp.asarray(rng.integers(0, 500, (M, L)).astype(np.float32))
        pt = jnp.asarray(rng.uniform(0, 5, (M, L)).astype(np.float32))
        Qcr = jnp.asarray(rng.integers(0, 900, (M, L)).astype(np.float32))
        extra = jnp.asarray(rng.uniform(0, 50, (M, L)).astype(np.float32))
        Qe = jnp.asarray(rng.integers(0, 900, M).astype(np.float32))
        pe = jnp.asarray(rng.uniform(1, 8, M).astype(np.float32))
        VCt = jnp.asarray(rng.uniform(0, 40, L).astype(np.float32))
        V_Ce = jnp.float32(rng.uniform(0, 40))
        ref = jax.jit(ops.route_scores_ref)(
            Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce
        )
        # interpret=True forces the emulated Pallas kernel (auto-dispatch
        # would lower to the reference off-TPU, making this vacuous)
        pal = ops.route_scores(
            Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce,
            block_m=bm, block_l=bl, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(pal[0]))
        np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(pal[1]))
        np.testing.assert_array_equal(np.asarray(ref[2]), np.asarray(pal[2]))


def test_network_policy_unknown_backend_raises():
    rng = np.random.default_rng(0)
    spec, state, Ce, Cc = _random_instance(rng, 5, 5)
    g = direct_graph(5, 5)
    pol = NetworkAwareDPPPolicy(score_backend="nope")
    with pytest.raises(ValueError, match="score_backend"):
        pol(state, spec, Ce, Cc, None, None,
            graph=g, Qt=jnp.zeros((5, 5)))


# -------------------------------------------------------- link dynamics


def _two_link_graph(size, bw):
    M = len(size)
    return make_graph(
        dest=[0, 1], bw=bw, pt=np.ones((M, 2), np.float32),
        region=[1, 2], size=size, primary=[0, 1],
    )


def test_qt_conservation_no_task_lost_or_duplicated():
    """Over a random dispatch stream, per (type, route):
    total injected == total delivered + still in flight, exactly."""
    rng = np.random.default_rng(11)
    M = 4
    g = _two_link_graph(
        size=rng.uniform(0.5, 6.0, M).astype(np.float32),
        bw=[7.0, 2.5],
    )
    ls = init_links(M, 2)
    injected = np.zeros((M, 2))
    delivered = np.zeros((M, 2))
    for t in range(60):
        dt = rng.integers(0, 5, (M, 2)).astype(np.float32)
        if t > 40:
            dt = np.zeros_like(dt)  # drain phase
        ls, dl = step_links(ls, g, jnp.asarray(dt))
        injected += dt
        delivered += np.asarray(dl)
        assert (np.asarray(dl) >= 0).all()
        assert (np.asarray(dl) == np.round(np.asarray(dl))).all()
    np.testing.assert_array_equal(injected, delivered + np.asarray(ls.Qt))
    # residual progress is always less than one task's worth of work
    assert (np.asarray(ls.prog) < np.asarray(g.size)[:, None] + 1e-5).all()


def test_bandwidth_cap_saturation():
    """A flooded route delivers at most bw size-units per slot, and
    keeps delivering at (near) line rate while backlogged."""
    M, bw = 3, 12.0
    size = np.array([1.0, 2.0, 4.0], np.float32)
    g = make_graph(
        dest=[0], bw=[bw], pt=np.ones((M, 1), np.float32),
        region=[1], size=size, primary=[0],
    )
    ls = init_links(M, 1)
    cum_work = 0.0
    for t in range(30):
        dt = jnp.full((M, 1), 10.0)  # 70 size-units/slot offered
        ls, dl = step_links(ls, g, dt)
        cum_work += float((np.asarray(dl)[:, 0] * size).sum())
        # the pipe can never have moved more than bw per elapsed slot
        # (a single slot may burst above bw when multi-slot progress
        # completes, but the running total is capped at line rate)
        assert cum_work <= bw * (t + 1) + 1e-3
    # ... and a backlogged pipe runs AT line rate, minus the partial
    # progress still parked on incomplete tasks
    assert cum_work >= bw * 30 - float((size * M).sum())
    assert float(np.asarray(ls.Qt).sum()) > 0  # genuinely congested


@pytest.mark.parametrize("size,bw", [(5.0, 2.0), (1.0, 1.0), (7.0, 3.0),
                                     (2.0, 8.0)])
def test_transfer_latency_is_ceil_size_over_bw(size, bw):
    g = make_graph(
        dest=[0], bw=[bw], pt=[[1.0]], region=[1], size=[size],
        primary=[0],
    )
    ls = init_links(1, 1)
    ls, dl = step_links(ls, g, jnp.ones((1, 1)))
    slots = 1
    while float(dl[0, 0]) == 0.0:
        ls, dl = step_links(ls, g, jnp.zeros((1, 1)))
        slots += 1
        assert slots < 50
    assert slots == int(np.ceil(size / bw))


def test_infinite_bandwidth_delivers_same_slot():
    g = direct_graph(3, 2)
    ls = init_links(3, 2)
    dt = jnp.asarray(np.random.default_rng(0).integers(0, 9, (3, 2)),
                     jnp.float32)
    ls, dl = step_links(ls, g, dt)
    np.testing.assert_array_equal(np.asarray(dl), np.asarray(dt))
    assert float(np.abs(np.asarray(ls.Qt)).max()) == 0.0
    assert float(np.abs(np.asarray(ls.prog)).max()) == 0.0


def test_full_simulation_conserves_tasks():
    """In the full WAN simulation: dispatched == delivered + in flight,
    and cloud queues only ever receive delivered tasks."""
    fleet = build_network_fleet(["congested-uplink"], per_kind=2, Tc=48)
    res = simulate_fleet(
        NetworkAwareDPPPolicy(V=0.1), fleet, 60,
        jax.random.PRNGKey(1),
    )
    disp = np.asarray(res.dispatched).sum(axis=1)
    deliv = np.asarray(res.delivered).sum(axis=1)
    qt_end = np.asarray(res.Qt)[:, -1].sum(axis=(1, 2))
    np.testing.assert_allclose(disp, deliv + qt_end, rtol=0, atol=1e-3)


# ------------------------------------------------- stacked-topology fleet


def test_registry_names():
    assert set(NETWORK_SCENARIOS) == {
        "star", "congested-uplink", "multi-region-uk-wan",
    }
    with pytest.raises(KeyError, match="registered"):
        build_network_fleet(["no-such-topology"], per_kind=1)
    # the advertised default kinds must actually stack (same L)
    assert build_network_fleet(per_kind=1, Tc=24).F == 2


def test_fleet_vmap_shape_dtype_contracts():
    """Stacked same-L topologies simulate in ONE jitted call with the
    documented shapes/dtypes on every NetSimResult field."""
    fleet = build_network_fleet(
        ["congested-uplink", "multi-region-uk-wan"], per_kind=3,
        M=4, N=3, Tc=24, seed=2,
    )
    F, M, N, T = fleet.F, 4, 3, 20
    L = fleet.graph.dest.shape[-1]
    assert F == 6 and L == 2 * N
    assert fleet.graph.pt.shape == (F, M, L)
    assert fleet.graph.dest.dtype == jnp.int32
    res = jax.jit(lambda k: simulate_fleet(
        NetworkAwareDPPPolicy(V=0.05), fleet, T, k
    ))(jax.random.PRNGKey(0))
    assert res.cum_emissions.shape == (F, T)
    assert res.Qe.shape == (F, T, M)
    assert res.Qc.shape == (F, T, M, N)
    assert res.Qt.shape == (F, T, M, L)
    assert res.energy_transfer.shape == (F, T)
    for field in res:
        if field is None:  # telemetry is off by default
            continue
        assert field.dtype == jnp.float32
        assert bool(jnp.isfinite(field).all())
    # cumulative emissions nondecreasing, distinct lanes distinct
    assert bool((jnp.diff(res.cum_emissions, axis=1) >= -1e-3).all())
    assert len(np.unique(np.asarray(res.cum_emissions[:, -1]))) > 1


def test_network_record_summary_matches_full():
    """record="summary" through the WAN simulator: scalar series
    bitwise, Qt/Qe/Qc collapse to length-1 final-state trajectories."""
    fleet = build_network_fleet(["congested-uplink"], per_kind=2, Tc=48)
    T, key = 30, jax.random.PRNGKey(4)
    pol = NetworkAwareDPPPolicy(V=0.1)
    full = simulate_fleet(pol, fleet, T, key)
    summ = simulate_fleet(pol, fleet, T, key, record="summary")
    for name in ("emissions", "cum_emissions", "dispatched", "delivered",
                 "processed", "energy_edge", "energy_transfer",
                 "energy_cloud"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full, name)), np.asarray(getattr(summ, name)),
            err_msg=name,
        )
    assert summ.Qt.shape[1] == 1
    np.testing.assert_array_equal(
        np.asarray(full.Qt[:, -1]), np.asarray(summ.Qt[:, 0])
    )
    np.testing.assert_array_equal(
        np.asarray(full.Qc[:, -1]), np.asarray(summ.Qc[:, 0])
    )


def test_star_topology_runs():
    fleet = build_network_fleet(["star"], per_kind=2, Tc=24)
    res = simulate_fleet(
        NetworkAwareDPPPolicy(V=0.05), fleet, 15, jax.random.PRNGKey(0)
    )
    assert res.Qt.shape[-1] == 5  # one route per cloud
    assert bool(jnp.isfinite(res.cum_emissions).all())


def test_static_route_policy_uses_primary_routes():
    rng = np.random.default_rng(2)
    M, N = 6, 4
    spec, state, Ce, Cc = _random_instance(rng, M, N)
    g = make_graph(
        dest=np.repeat(np.arange(N), 2),
        bw=np.full(2 * N, 50.0),
        pt=np.ones((M, 2 * N), np.float32),
        region=np.repeat(np.arange(1, N + 1), 2),
        size=np.ones(M, np.float32),
        primary=2 * np.arange(N) + 1,  # the odd links
    )
    base = CarbonIntensityPolicy(V=0.05)
    pol = StaticRoutePolicy(base)
    act = pol(state, spec, Ce, Cc, None, None,
              graph=g, Qt=jnp.zeros((M, 2 * N)))
    d = np.asarray(base(state, spec, Ce, Cc, None, None).d)
    dt = np.asarray(act.dt)
    np.testing.assert_array_equal(dt[:, 1::2], d)   # primaries carry d
    assert (dt[:, 0::2] == 0).all()                 # alternates unused


def test_route_aware_beats_transfer_blind_on_congested_uplink():
    """The subsystem's acceptance property, test-sized: on the
    congested-uplink topology the route-aware policy emits less than
    the transfer-blind baseline while doing comparable work."""
    fleet = build_network_fleet(["congested-uplink"], per_kind=4, Tc=96,
                                seed=0)
    T, key = 120, jax.random.PRNGKey(0)
    blind = simulate_fleet(
        StaticRoutePolicy(CarbonIntensityPolicy(V=0.1)),
        fleet, T, key,
    )
    aware = simulate_fleet(
        NetworkAwareDPPPolicy(V=0.1), fleet, T, key,
    )
    em_blind = float(blind.cum_emissions[:, -1].mean())
    em_aware = float(aware.cum_emissions[:, -1].mean())
    assert em_aware < 0.95 * em_blind, (em_aware, em_blind)
    # comparable throughput: within 10% of the blind policy's work
    assert (float(aware.processed.sum()) >
            0.9 * float(blind.processed.sum()))


def test_stack_graphs_rejects_mixed_shapes():
    from repro.network import stack_graphs

    with pytest.raises(ValueError, match="share"):
        stack_graphs([direct_graph(3, 2), direct_graph(3, 4)])


def test_make_graph_rejects_degenerate_sizes_and_bandwidth():
    """size=0 would turn floor(prog/size) into NaN deep inside the
    scan; the validating constructor must refuse it up front."""
    ok = dict(dest=[0], bw=[1.0], pt=[[1.0]], region=[1], size=[1.0],
              primary=[0])
    make_graph(**ok)  # sanity
    with pytest.raises(ValueError, match="size"):
        make_graph(**{**ok, "size": [0.0]})
    with pytest.raises(ValueError, match="bw"):
        make_graph(**{**ok, "bw": [-1.0]})
