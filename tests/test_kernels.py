"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU), as required for every Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

jax.config.update("jax_enable_x64", False)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------- flash ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,Sq,Skv,hd,bq,bk",
    [
        (1, 4, 4, 128, 128, 64, 128, 128),   # MHA, single block
        (2, 4, 2, 256, 256, 64, 128, 128),   # GQA 2:1
        (1, 8, 1, 128, 256, 32, 64, 128),    # MQA, rectangular, small blocks
        (2, 2, 2, 64, 64, 128, 64, 64),      # small seq
        (1, 4, 2, 384, 256, 64, 128, 128),   # non-equal q/kv lens
    ],
)
@pytest.mark.parametrize("mode", ["causal", "full", "prefix"])
def test_flash_attention_sweep(B, H, K, Sq, Skv, hd, bq, bk, mode, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * H + Sq), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, Skv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, Skv, hd)).astype(dtype)
    prefix = 32 if mode == "prefix" else 0
    got = ops.flash_attention(
        q, k, v, mask_mode=mode, prefix_len=prefix, bq=bq, bk=bk,
        interpret=True,
    )
    want = ops.flash_attention_ref(q, k, v, mask_mode=mode, prefix_len=prefix)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype),
    )


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model's chunked-attention path (both vs the
    naive oracle) -- the integration contract used at serve time."""
    from repro.models.layers import attention_scores_chunked

    B, H, K, S, hd = 1, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, S, K, H // K, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    y_model = attention_scores_chunked(
        q, k, v, mask_mode="causal", q_offset=0, chunk=64
    )  # [B,S,K,G,hd]
    qk = jnp.transpose(
        q.reshape(B, S, H, hd), (0, 2, 1, 3)
    )  # [B,H,S,hd], head order h = kvhead*G + g
    kk = jnp.transpose(k, (0, 2, 1, 3))
    vk = jnp.transpose(v, (0, 2, 1, 3))
    y_kernel = ops.flash_attention(qk, kk, vk, mask_mode="causal",
                                   bq=64, bk=64, interpret=True)
    y_kernel = jnp.transpose(y_kernel, (0, 2, 1, 3)).reshape(
        B, S, K, H // K, hd
    )
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_model), rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------------------------ ssd ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,nc,l,H,P,N,bh",
    [
        (1, 2, 16, 8, 8, 16, 8),
        (2, 3, 32, 16, 8, 16, 8),
        (1, 1, 64, 4, 16, 32, 4),
        (2, 2, 32, 16, 16, 8, 16),  # bh == H
    ],
)
def test_ssd_chunk_sweep(B, nc, l, H, P, N, bh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(l + H), 4)
    a = -jax.nn.softplus(jax.random.normal(ks[0], (B, nc, l, H))).astype(dtype)
    x = jax.random.normal(ks[1], (B, nc, l, H, P)).astype(dtype)
    Bm = jax.random.normal(ks[2], (B, nc, l, N)).astype(dtype)
    Cm = jax.random.normal(ks[3], (B, nc, l, N)).astype(dtype)
    got = ops.ssd_chunk_intra(a, x, Bm, Cm, block_heads=bh, interpret=True)
    want = ops.ssd_chunk_intra_ref(a, x, Bm, Cm)
    for g, w, name in zip(got, want, ["y_diag", "S_c", "total"]):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            err_msg=name, **tol(dtype),
        )


def test_ssd_kernel_plugs_into_full_ssd():
    """Replacing the XLA intra-chunk computation with the kernel output
    reproduces models.mamba2.ssd_chunked end to end."""
    from repro.models import mamba2

    B, S, H, P, N, chunk = 1, 64, 4, 8, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, hT_ref = mamba2.ssd_chunked(x, dt, A, Bm, Cm, chunk)

    nc = S // chunk
    a = (dt * A[None, None]).reshape(B, nc, chunk, H)
    xd = (x * dt[..., None]).reshape(B, nc, chunk, H, P)
    Bc = Bm.reshape(B, nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N)
    y_diag, S_c, total = ops.ssd_chunk_intra(a, xd, Bc, Cc, block_heads=4,
                                             interpret=True)

    def scan_fn(h, inp):
        S_i, tot_i = inp
        return h * tot_i[..., None, None] + S_i, h

    hT, h_starts = jax.lax.scan(
        scan_fn, jnp.zeros((B, H, N, P)),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_starts = jnp.moveaxis(h_starts, 0, 1)
    ci = jnp.cumsum(a, axis=2)
    y_off = jnp.einsum(
        "bcln,bclh,bchnp->bclhp", Cc, jnp.exp(ci), h_starts
    )
    y = (y_diag + y_off).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- carbon ----
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize(
    "M,N,bm,bn",
    [
        (256, 256, 128, 128),
        (512, 1024, 256, 256),
        (128, 128, 128, 128),
        (1024, 256, 256, 64),
        # non-multiple-of-block shapes exercise the internal padding
        (100, 37, 64, 16),
        (257, 129, 128, 128),
        (5, 5, 256, 256),
        (300, 200, 128, 128),
    ],
)
def test_carbon_scores_sweep(M, N, bm, bn, dtype):
    ks = jax.random.split(jax.random.PRNGKey(M + N), 5)
    Qc = jax.random.randint(ks[0], (M, N), 0, 5000).astype(dtype)
    pc = jax.random.uniform(ks[1], (M, N), minval=1, maxval=100).astype(dtype)
    Qe = jax.random.randint(ks[2], (M,), 0, 5000).astype(dtype)
    pe = jax.random.uniform(ks[3], (M,), minval=1, maxval=10).astype(dtype)
    Cc = jax.random.uniform(ks[4], (N,), minval=0, maxval=700).astype(dtype)
    VCe = jnp.float32(0.05 * 350.0)
    c, n1, b = ops.carbon_scores(Qc, pc, Qe, pe, Cc, VCe, block_m=bm,
                                 block_n=bn, interpret=True)
    cr, n1r, br = ops.carbon_scores_ref(Qc, pc, Qe, pe, Cc, VCe)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), rtol=1e-4,
                               atol=1e-2)
    # argmin ties can differ between tiled and flat reduction only when
    # equal values exist; compare the achieved minima instead of indices.
    np.testing.assert_allclose(
        np.asarray(Qc)[np.arange(M), np.asarray(n1)],
        np.asarray(Qc)[np.arange(M), np.asarray(n1r)],
    )
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-4,
                               atol=1e-2)


def test_carbon_kernel_policy_equivalence():
    """Policy decisions built from kernel outputs == vectorized policy."""
    from repro.core.policies import CarbonIntensityPolicy
    from repro.core.queueing import NetworkSpec, NetworkState

    rng = np.random.default_rng(0)
    M, N = 256, 128
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=500.0,
        Pc=rng.uniform(100, 1000, N).astype(np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    V = 0.05
    c, n1, b = ops.carbon_scores(
        state.Qc, jnp.asarray(spec.pc), state.Qe, jnp.asarray(spec.pe),
        Cc, jnp.float32(V * Ce), block_m=128, block_n=128, interpret=True,
    )
    # dispatch coefficients used by Algorithm 1 must agree
    pol = CarbonIntensityPolicy(V=V)
    n1_pol = jnp.argmin(state.Qc, axis=1)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n1_pol))
    act = pol(state, spec, Ce, Cc, None, None)
    # b<0 is necessary for any dispatch of type m
    dispatched = np.asarray(act.d).sum(1) > 0
    assert np.all(np.asarray(b)[dispatched] < 0)


# --------------------------------------------------------------- decode ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,S,hd,bs,pos",
    [
        (2, 8, 2, 512, 64, 256, 511),    # GQA, full cache
        (1, 4, 4, 1024, 64, 512, 100),   # MHA, partial cache
        (2, 8, 1, 256, 128, 128, 0),     # MQA, single valid slot
        (1, 16, 2, 2048, 64, 512, 1500), # long cache, mid position
    ],
)
def test_flash_decode_sweep(B, H, K, S, hd, bs, pos, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + pos), 3)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd)).astype(dtype)
    got = ops.flash_decode(q, k, v, jnp.int32(pos), block_s=bs,
                           interpret=True)
    want = ops.flash_decode_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype),
    )


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the model's decode_attention math (post cache update)."""
    import dataclasses

    from repro.configs import registry
    from repro.models import layers as L

    cfg = dataclasses.replace(
        registry.get_smoke_config("internlm2_20b"), rope_fraction=0.0
    )
    B, S = 2, 64
    K, H, hd = cfg.n_kv_heads, cfg.n_heads, cfg.resolved_head_dim
    key = jax.random.PRNGKey(0)
    p = L.init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, cfg.d_model))
    ck = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    cv = jax.random.normal(jax.random.fold_in(key, 3), (B, S, K, hd))
    pos = jnp.int32(40)
    y_model, (ck2, cv2) = L.decode_attention(p, x, cfg, ck, cv, pos)

    # rebuild the same q and the updated cache, then run the kernel
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]  # [B,H,hd]
    y_kernel = ops.flash_decode(q, ck2, cv2, pos, block_s=32,
                                interpret=True)
    y_kernel = jnp.einsum(
        "bhk,hkd->bd", y_kernel, p["wo"]
    )[:, None, :]
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_model), rtol=2e-4, atol=2e-4
    )
