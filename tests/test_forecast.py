"""Forecast subsystem tests: forecaster contracts (shape/dtype, vmap),
LookaheadDPPPolicy H=1 bit-parity on both score backends, forecast-
quality regressions, the error model, and the clairvoyant-horizon
oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_workloads import paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    LookaheadDPPPolicy,
    TableCarbonSource,
    UniformArrivals,
    diurnal_table,
    oracle_emissions_horizon,
    simulate,
    simulate_fleet,
)
from repro.core.queueing import NetworkSpec, NetworkState
from repro.forecast import (
    ClairvoyantTableForecaster,
    EWMAForecaster,
    ForecastErrorModel,
    ForecastedCarbonSource,
    PersistenceForecaster,
    RidgeARForecaster,
    SeasonalNaiveForecaster,
    forecast_errors,
    rolling_forecasts,
)

jax.config.update("jax_enable_x64", False)

ALL_FORECASTERS = [
    PersistenceForecaster,
    SeasonalNaiveForecaster,
    EWMAForecaster,
    RidgeARForecaster,
]


# ---------------------------------------------------------------- contracts


@pytest.mark.parametrize("cls", ALL_FORECASTERS)
@pytest.mark.parametrize("H", [1, 4, 8])
def test_forecaster_shape_dtype(cls, H):
    fc = cls(H=H)
    tab = diurnal_table(40, 3, np.random.default_rng(0))
    out = rolling_forecasts(fc, tab)
    assert out.shape == (40, H, 4)
    assert out.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("cls", ALL_FORECASTERS)
def test_forecaster_vmaps_over_tables(cls):
    """The whole rolling evaluation vmaps over a stack of tables --
    the property the fleet engine relies on."""
    fc = cls(H=6)
    rng = np.random.default_rng(1)
    tabs = jnp.stack(
        [jnp.asarray(diurnal_table(30, 4, rng)) for _ in range(5)]
    )
    out = jax.jit(jax.vmap(lambda t: rolling_forecasts(fc, t)))(tabs)
    assert out.shape == (5, 30, 6, 5)
    assert out.dtype == jnp.float32
    # lanes see different tables -> different forecasts
    assert not np.allclose(np.asarray(out[0]), np.asarray(out[1]))


@pytest.mark.parametrize("cls", ALL_FORECASTERS)
def test_row0_is_observed_present(cls):
    """Contract: predict()[0] is the row just observed."""
    fc = cls(H=5)
    tab = diurnal_table(60, 3, np.random.default_rng(2))
    out = np.asarray(rolling_forecasts(fc, tab))
    np.testing.assert_allclose(out[:, 0, :], tab, rtol=1e-6)


def test_clairvoyant_table_forecaster_exact_and_wrapping():
    tab = diurnal_table(20, 2, np.random.default_rng(3))
    fc = ClairvoyantTableForecaster(H=6)
    carry = fc.init(2, table=tab)
    pred = np.asarray(fc.predict(carry, jnp.int32(17)))
    expect = tab[(17 + np.arange(6)) % 20]
    np.testing.assert_allclose(pred, expect, rtol=1e-6)
    with pytest.raises(ValueError, match="playback table"):
        fc.init(2, table=None)


def test_forecasted_carbon_source_serves_truth_and_forecast():
    base = TableCarbonSource(table=diurnal_table(
        30, 3, np.random.default_rng(4)
    ))
    src = ForecastedCarbonSource(base, H=4)
    key = jax.random.PRNGKey(0)
    Ce, Cc = src(jnp.int32(5), key)  # passthrough
    Ce0, Cc0 = base(jnp.int32(5), key)
    assert float(Ce) == float(Ce0)
    np.testing.assert_array_equal(np.asarray(Cc), np.asarray(Cc0))
    carry = src.init(3, key=key)
    pred = np.asarray(src.predict(carry, jnp.int32(5)))
    assert pred.shape == (4, 4)
    np.testing.assert_allclose(pred[0], base.table[5], rtol=1e-6)
    np.testing.assert_allclose(pred[2], base.table[7], rtol=1e-6)


def test_forecast_errors_mae_is_per_entry():
    """Regression: MAE must be normalized over ALL scored entries
    (slots x leads x regions), not slots x leads -- an earlier version
    inflated it by a factor of N+1."""
    tab = np.zeros((10, 4), np.float32)
    tab[5:] = 1.0  # single step; persistence is wrong exactly at t=4
    e = forecast_errors(PersistenceForecaster(H=2), tab)
    # 9 valid (slot, lead) pairs, one wrong, |err|=1 in all 4 regions:
    # per-entry MAE = 4 / (9*4) = 1/9.
    assert float(e["mae"]) == pytest.approx(1.0 / 9.0, rel=1e-5)


def test_error_model_decorrelates_across_keys():
    """Regression: under simulate_fleet's vmap every lane must draw its
    own noise realization (the key threads through the carry)."""
    em = ForecastErrorModel(noise=0.3, seed=7)
    truth = jnp.full((4, 3), 200.0, jnp.float32)
    a = np.asarray(em.apply(truth, jnp.int32(0), key=jax.random.PRNGKey(1)))
    b = np.asarray(em.apply(truth, jnp.int32(0), key=jax.random.PRNGKey(2)))
    assert not np.allclose(a[1:], b[1:])


def test_lookahead_rejects_short_forecast():
    rng = np.random.default_rng(6)
    spec, state, Ce, Cc = _random_instance(rng, 5, 3)
    short = jnp.zeros((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="H >= 8"):
        LookaheadDPPPolicy(V=0.1, H=8)(
            state, spec, Ce, Cc, None, None, forecast=short
        )


# ------------------------------------------------------------- error model


def test_error_model_lead0_exact_noise_grows_with_lead():
    em = ForecastErrorModel(noise=0.2, seed=1)
    truth = jnp.full((8, 4), 300.0, jnp.float32)
    devs = []
    for t in range(50):
        pred = np.asarray(em.apply(truth, jnp.int32(t)))
        assert pred.min() >= 0.0
        devs.append(np.abs(pred - np.asarray(truth)))
    devs = np.stack(devs)  # [50, 8, 4]
    np.testing.assert_array_equal(devs[:, 0, :], 0.0)  # present is known
    mean_dev = devs.mean(axis=(0, 2))  # per-lead
    assert mean_dev[1] > 0.0
    assert mean_dev[-1] > 2.0 * mean_dev[1]  # heteroscedastic growth


def test_error_model_bias():
    em = ForecastErrorModel(bias=0.5)
    truth = jnp.full((4, 3), 100.0, jnp.float32)
    pred = np.asarray(em.apply(truth, jnp.int32(0)))
    np.testing.assert_allclose(pred[0], 100.0)
    np.testing.assert_allclose(pred[1:], 150.0)


# ----------------------------------------------------- H=1 parity (tentpole)


def _random_instance(rng, M, N):
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=float(rng.uniform(100, 2000)),
        Pc=rng.uniform(100, 5000, N).astype(np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(rng.uniform(0, 700))
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    return spec, state, Ce, Cc


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_h1_bit_parity_single_call(backend):
    """LookaheadDPPPolicy(H=1) == CarbonIntensityPolicy bitwise, per
    action, on randomized specs -- even with an adversarial forecast
    (row 0 is overwritten with the observed intensities)."""
    rng = np.random.default_rng(0)
    for trial in range(5):
        M, N = int(rng.integers(3, 20)), int(rng.integers(2, 10))
        spec, state, Ce, Cc = _random_instance(rng, M, N)
        forecast = jnp.asarray(
            rng.uniform(0, 700, (1, N + 1)).astype(np.float32)
        )
        myo = CarbonIntensityPolicy(V=0.05, score_backend=backend)
        la = LookaheadDPPPolicy(
            V=0.05, H=1, defer_weight=5.0, score_backend=backend
        )
        a0 = jax.jit(lambda s: myo(s, spec, Ce, Cc, None, None))(state)
        a1 = jax.jit(
            lambda s: la(s, spec, Ce, Cc, None, None, forecast=forecast)
        )(state)
        np.testing.assert_array_equal(
            np.asarray(a0.d), np.asarray(a1.d), err_msg=f"trial {trial}"
        )
        np.testing.assert_array_equal(
            np.asarray(a0.w), np.asarray(a1.w), err_msg=f"trial {trial}"
        )


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_h1_bit_parity_full_simulation(backend):
    """Parity holds over a whole simulate() run with the forecaster
    threading through the scan carry."""
    spec = paper_spec()
    tab = diurnal_table(60, 5, np.random.default_rng(1))
    src = TableCarbonSource(table=tab)
    arrive = UniformArrivals(M=5, amax=300)
    key = jax.random.PRNGKey(2)
    r0 = simulate(
        CarbonIntensityPolicy(V=0.05, score_backend=backend),
        spec, src, arrive, 60, key,
    )
    r1 = simulate(
        LookaheadDPPPolicy(V=0.05, H=1, score_backend=backend),
        spec, src, arrive, 60, key,
        forecaster=ClairvoyantTableForecaster(H=1),
    )
    np.testing.assert_array_equal(
        np.asarray(r0.cum_emissions), np.asarray(r1.cum_emissions)
    )
    np.testing.assert_array_equal(np.asarray(r0.Qe), np.asarray(r1.Qe))
    np.testing.assert_array_equal(np.asarray(r0.Qc), np.asarray(r1.Qc))


def test_lookahead_without_forecast_degrades_to_myopic():
    rng = np.random.default_rng(5)
    spec, state, Ce, Cc = _random_instance(rng, 8, 4)
    a0 = CarbonIntensityPolicy(V=0.1)(state, spec, Ce, Cc, None, None)
    a1 = LookaheadDPPPolicy(V=0.1, H=8)(state, spec, Ce, Cc, None, None)
    np.testing.assert_array_equal(np.asarray(a0.d), np.asarray(a1.d))
    np.testing.assert_array_equal(np.asarray(a0.w), np.asarray(a1.w))


# ----------------------------------------------- lookahead value + regression


def test_lookahead_reduces_emissions_on_diurnal_fleet():
    """Small in-test version of the acceptance bench: H=8 + perfect
    forecasts beats the myopic policy on emissions on the diurnal
    fleet scenario, without exploding the backlog."""
    from repro.configs.fleet_scenarios import build_fleet

    fleet = build_fleet(["diurnal"], per_kind=4, Tc=96, seed=0)
    key = jax.random.PRNGKey(0)
    T = 96

    def run(policy, forecaster=None):
        res = jax.jit(lambda: simulate_fleet(
            policy, fleet, T, key, forecaster=forecaster
        ))()
        em = np.asarray(res.cum_emissions[:, -1])
        bl = np.asarray(res.Qe[:, -1].sum(-1) + res.Qc[:, -1].sum((-2, -1)))
        return em, bl

    em0, bl0 = run(CarbonIntensityPolicy(V=0.2))
    em1, bl1 = run(
        LookaheadDPPPolicy(V=0.2, H=8, discount=1.0,
                           defer_weight=3.0),
        ClairvoyantTableForecaster(H=8),
    )
    assert em1.mean() < 0.95 * em0.mean()  # real reduction
    assert bl1.mean() < 1.5 * bl0.mean()   # bounded deferral price


def test_seasonal_naive_beats_persistence_on_diurnal():
    """Regression: on diurnal traces the seasonal-naive forecaster must
    dominate persistence (that gap is the whole reason the period-aware
    forecaster exists)."""
    rng = np.random.default_rng(7)
    for trial in range(3):
        tab = diurnal_table(48 * 4, 4, rng)
        e_per = forecast_errors(PersistenceForecaster(H=8), tab, burn_in=48)
        e_sea = forecast_errors(
            SeasonalNaiveForecaster(H=8, period=48), tab, burn_in=48
        )
        assert float(e_sea["mae"]) < 0.8 * float(e_per["mae"]), (
            f"trial {trial}: seasonal {float(e_sea['mae']):.1f} vs "
            f"persistence {float(e_per['mae']):.1f}"
        )


def test_ridge_ar_beats_ewma_on_diurnal():
    """The fitted AR model should beat the level-only EWMA on a signal
    that is mostly structure."""
    tab = diurnal_table(48 * 4, 4, np.random.default_rng(9))
    e_ar = forecast_errors(RidgeARForecaster(H=8), tab, burn_in=64)
    e_ew = forecast_errors(EWMAForecaster(H=8), tab, burn_in=64)
    assert float(e_ar["mae"]) < float(e_ew["mae"])


# ------------------------------------------------------------ horizon oracle


def test_oracle_horizon_monotone_and_consistent():
    tab = diurnal_table(96, 3, np.random.default_rng(11))
    rng = np.random.default_rng(12)
    ee = rng.uniform(0, 50, 96)
    ec = rng.uniform(0, 80, (96, 3))
    actual = float(np.sum(ee * tab[:, 0]) + np.sum(ec * tab[:, 1:]))
    lb1 = oracle_emissions_horizon(tab, ee, ec, horizon=1)
    lb8 = oracle_emissions_horizon(tab, ee, ec, horizon=8)
    lb_full = oracle_emissions_horizon(tab, ee, ec, horizon=None)
    # H=1 re-prices every kWh at its own slot: exactly the actual cost.
    assert lb1 == pytest.approx(actual, rel=1e-6)
    # longer windows only cheapen the relaxation
    assert lb_full <= lb8 <= lb1
    assert lb_full < 0.99 * lb1  # diurnal spread leaves real value


def test_oracle_horizon_rejects_mismatched_columns():
    tab = diurnal_table(10, 3, np.random.default_rng(0))
    with pytest.raises(ValueError, match="columns"):
        oracle_emissions_horizon(
            tab, np.zeros(10), np.zeros((10, 2)), horizon=2
        )


def test_per_lane_forecast_error_sweep_in_one_call():
    """ISSUE-4 satellite: FleetScenario.err_bias/err_noise sweep
    forecast quality ACROSS LANES of one compiled simulate_fleet call.
    A zero-error lane reproduces the no-override run exactly; noisier
    lanes genuinely diverge."""
    from repro.configs.fleet_scenarios import build_fleet
    from repro.core.simulator import sweep_forecast_errors

    fleet = build_fleet(["diurnal-slack"], per_kind=4, Tc=96, seed=0)
    noises = jnp.asarray([0.0, 0.1, 0.3, 0.6])
    fleet_err = sweep_forecast_errors(fleet, bias=0.0, noise=noises)
    assert fleet_err.err_bias.shape == (4,)  # scalar bias broadcast

    pol = LookaheadDPPPolicy(V=0.2, H=8, discount=0.98,
                             defer_weight=2.0)
    fc = ClairvoyantTableForecaster(H=8)
    key = jax.random.PRNGKey(3)
    T = 72
    res = jax.jit(lambda k: simulate_fleet(
        pol, fleet_err, T, k, forecaster=fc
    ))(key)
    base = simulate_fleet(pol, fleet, T, key, forecaster=fc)

    # lane 0 carries (bias=0, noise=0): the traced-override path must
    # reproduce the exact-forecast run -- queue trajectories bitwise.
    np.testing.assert_array_equal(
        np.asarray(res.Qe[0]), np.asarray(base.Qe[0])
    )
    np.testing.assert_allclose(
        np.asarray(res.cum_emissions[0]),
        np.asarray(base.cum_emissions[0]), rtol=1e-6,
    )
    # noisy lanes take different actions than their exact twins
    assert not np.array_equal(np.asarray(res.Qe[3]), np.asarray(base.Qe[3]))


def test_per_lane_bias_shifts_deferral():
    """Systematic over-prediction of future intensity (positive bias
    inflates forecast troughs less than it inflates the future in
    general... the sign contract: bias != 0 changes behavior) -- and
    the per-lane bias axis reaches the forecaster."""
    from repro.configs.fleet_scenarios import build_fleet
    from repro.core.simulator import sweep_forecast_errors

    fleet = build_fleet(["diurnal-slack"], per_kind=2, Tc=96, seed=1)
    fleet_err = sweep_forecast_errors(
        fleet, bias=jnp.asarray([0.0, -0.5]), noise=0.0
    )
    pol = LookaheadDPPPolicy(V=0.2, H=8, discount=1.0,
                             defer_weight=3.0)
    res = simulate_fleet(
        pol, fleet_err, 72, jax.random.PRNGKey(0),
        forecaster=ClairvoyantTableForecaster(H=8),
    )
    base = simulate_fleet(
        pol, fleet, 72, jax.random.PRNGKey(0),
        forecaster=ClairvoyantTableForecaster(H=8),
    )
    # bias=0 lane matches; bias=-0.5 lane (hallucinated deep troughs ->
    # over-deferral) diverges
    np.testing.assert_array_equal(
        np.asarray(res.Qe[0]), np.asarray(base.Qe[0])
    )
    assert not np.array_equal(np.asarray(res.Qc[1]), np.asarray(base.Qc[1]))
