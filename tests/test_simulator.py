"""Simulator integration tests: stability, emissions accounting, repro of
paper's headline comparisons at reduced horizon."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UKRegionalTraceSource,
    UniformArrivals,
    simulate,
    simulate_vsweep,
)
from repro.configs.paper_workloads import V_PAPER, paper_spec


@pytest.fixture(scope="module")
def results():
    spec = paper_spec()
    key = jax.random.PRNGKey(0)
    T = 800
    carbon = RandomCarbonSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    rc = jax.jit(
        lambda: simulate(
            CarbonIntensityPolicy(V=V_PAPER), spec, carbon, arrive, T, key
        )
    )()
    rq = jax.jit(
        lambda: simulate(QueueLengthPolicy(), spec, carbon, arrive, T, key)
    )()
    return rc, rq, T


def test_emission_accounting_consistent(results):
    rc, _, _ = results
    np.testing.assert_allclose(
        np.asarray(rc.cum_emissions),
        np.cumsum(np.asarray(rc.emissions)),
        rtol=1e-5,
    )
    assert np.all(np.asarray(rc.emissions) >= 0)


def test_energy_constraints_never_violated(results):
    rc, rq, _ = results
    spec = paper_spec()
    for r in (rc, rq):
        assert np.all(np.asarray(r.energy_edge) <= spec.Pe + 1e-2)
        assert np.all(
            np.asarray(r.energy_cloud) <= np.asarray(spec.Pc)[None, :] + 1e-2
        )


def test_carbon_policy_beats_queue_policy(results):
    rc, rq, _ = results
    red = 1 - float(rc.cum_emissions[-1]) / float(rq.cum_emissions[-1])
    # paper reports 63% at T~2000; at T=800 with our seed it's > 50%
    assert red > 0.45, f"only {red:.2%} reduction"


def test_mean_rate_stability(results):
    rc, _, T = results
    # backlog grows sublinearly: Q(T)/T small and decreasing in T
    backlog_frac = float(rc.final_backlog) / T
    assert backlog_frac < 60.0
    # stronger: windowed averages of Qe flatten out (no linear blowup)
    qe = np.asarray(rc.Qe).sum(1)
    first, last = qe[: T // 4].mean(), qe[-T // 4 :].mean()
    assert last < 50 * max(first, 1.0)


def test_realworld_trace_reduction():
    spec = paper_spec()
    key = jax.random.PRNGKey(0)
    T = 600
    carbon = UKRegionalTraceSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    rc = simulate(CarbonIntensityPolicy(V=V_PAPER), spec, carbon, arrive, T, key)
    rq = simulate(QueueLengthPolicy(), spec, carbon, arrive, T, key)
    red = 1 - float(rc.cum_emissions[-1]) / float(rq.cum_emissions[-1])
    assert red > 0.35  # paper: 54% at T~2000


def test_vsweep_tradeoff_monotone():
    """Theorem 1: larger V -> lower emissions, larger queues (Fig 2+4)."""
    spec = paper_spec()
    Vs = jnp.array([0.005, 0.05, 0.5])
    res = simulate_vsweep(
        lambda V: CarbonIntensityPolicy(V=V),
        Vs,
        spec,
        RandomCarbonSource(N=5),
        UniformArrivals(M=5, amax=400),
        500,
        jax.random.PRNGKey(1),
    )
    cum = np.asarray(res.cum_emissions[:, -1])
    qe_mean = np.asarray(res.Qe).mean((1, 2))
    assert cum[0] > cum[1] > cum[2]
    assert qe_mean[0] < qe_mean[2]


def test_record_summary_matches_full_bitwise():
    """record="summary" keeps the per-slot scalar series bitwise equal
    to full recording and returns the final state as a length-1
    trajectory (so Qe[-1]/final_backlog work unchanged)."""
    spec = paper_spec()
    key = jax.random.PRNGKey(3)
    args = (
        CarbonIntensityPolicy(V=0.05), spec, RandomCarbonSource(N=5),
        UniformArrivals(M=5, amax=400), 120, key,
    )
    full = simulate(*args)
    summ = simulate(*args, record="summary")
    for name in ("emissions", "cum_emissions", "dispatched", "processed",
                 "energy_edge", "energy_cloud"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full, name)), np.asarray(getattr(summ, name)),
            err_msg=name,
        )
    assert summ.Qe.shape == (1, 5)
    assert summ.Qc.shape == (1, 5, 5)
    np.testing.assert_array_equal(np.asarray(full.Qe[-1]),
                                  np.asarray(summ.Qe[0]))
    np.testing.assert_array_equal(np.asarray(full.Qc[-1]),
                                  np.asarray(summ.Qc[0]))
    np.testing.assert_array_equal(np.asarray(full.final_backlog),
                                  np.asarray(summ.final_backlog))


def test_record_stride_snapshots_every_k_slots():
    """record=k snapshots the post-step state at slots k-1, 2k-1, ...
    (exactly the rows full recording stacks there) and keeps the scalar
    series identical."""
    spec = paper_spec()
    key = jax.random.PRNGKey(4)
    args = (
        CarbonIntensityPolicy(V=0.05), spec, RandomCarbonSource(N=5),
        UniformArrivals(M=5, amax=400), 120, key,
    )
    full = simulate(*args)
    k = 8
    strided = simulate(*args, record=k)
    assert strided.Qe.shape == (120 // k, 5)
    np.testing.assert_array_equal(
        np.asarray(full.Qe[k - 1 :: k]), np.asarray(strided.Qe)
    )
    np.testing.assert_array_equal(
        np.asarray(full.Qc[k - 1 :: k]), np.asarray(strided.Qc)
    )
    np.testing.assert_array_equal(
        np.asarray(full.emissions), np.asarray(strided.emissions)
    )


def test_record_rejects_bad_stride():
    spec = paper_spec()
    args = (
        CarbonIntensityPolicy(V=0.05), spec, RandomCarbonSource(N=5),
        UniformArrivals(M=5, amax=400), 100, jax.random.PRNGKey(0),
    )
    with pytest.raises(ValueError, match="record"):
        simulate(*args, record=7)  # 7 does not divide 100
    with pytest.raises(ValueError, match="record"):
        simulate(*args, record=0)


def test_simulation_deterministic_given_key():
    spec = paper_spec()
    args = (
        CarbonIntensityPolicy(V=0.05),
        spec,
        RandomCarbonSource(N=5),
        UniformArrivals(M=5, amax=400),
        100,
        jax.random.PRNGKey(7),
    )
    r1, r2 = simulate(*args), simulate(*args)
    np.testing.assert_array_equal(
        np.asarray(r1.cum_emissions), np.asarray(r2.cum_emissions)
    )
