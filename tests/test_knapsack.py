"""Knapsack oracle tests: JAX DP == numpy exact DP; both beat/equal greedy."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.knapsack import bounded_knapsack_min, exact_knapsack_min_py


@pytest.mark.parametrize("seed", range(10))
def test_jax_dp_matches_numpy_dp_value(seed):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(2, 6))
    scores = rng.uniform(-10, 5, M).astype(np.float32)
    weights = rng.uniform(1, 6, M).astype(np.float32)
    caps = rng.integers(0, 12, M).astype(np.float32)
    budget = float(rng.uniform(5, 30))
    counts_np, val_np = exact_knapsack_min_py(
        scores, weights, caps, budget, resolution=512
    )
    counts_jx = np.asarray(
        bounded_knapsack_min(
            jnp.asarray(scores),
            jnp.asarray(weights),
            jnp.asarray(caps),
            jnp.asarray(budget),
            grid=512,
        )
    )
    val_jx = float(np.dot(scores, counts_jx))
    # same grid -> same optimum value (counts may differ on ties)
    assert val_jx <= val_np + 1e-3
    assert val_np <= val_jx + 1e-3
    # feasibility of both
    assert np.dot(weights, counts_jx) <= budget + 1e-4
    assert np.all(counts_jx <= caps + 1e-6)
    assert np.all(counts_jx >= 0)


def test_positive_scores_take_nothing():
    counts, val = exact_knapsack_min_py(
        np.array([1.0, 2.0]), np.array([1.0, 1.0]), np.array([5.0, 5.0]), 10.0
    )
    assert val == 0 and np.all(counts == 0)
    cj = np.asarray(
        bounded_knapsack_min(
            jnp.array([1.0, 2.0]),
            jnp.array([1.0, 1.0]),
            jnp.array([5.0, 5.0]),
            jnp.asarray(10.0),
        )
    )
    assert np.all(cj == 0)


def test_known_instance():
    # two items: score -3/weight 2 (ratio -1.5), score -2/weight 1 (ratio -2)
    # budget 4, caps 10: optimal = 4x item2? value -8 vs 2x item1 = -6;
    # mixed: 1x item1 + 2x item2 = -7. Optimum: item2 x4 = -8.
    counts, val = exact_knapsack_min_py(
        np.array([-3.0, -2.0]), np.array([2.0, 1.0]), np.array([10.0, 10.0]), 4.0
    )
    assert val == -8.0
    np.testing.assert_allclose(counts, [0, 4])


def test_caps_respected():
    # cap item2 at 1: candidates are 2x item1 (w4, -6) or
    # 1x item1 + 1x item2 (w3, -5). Optimum: [2, 0] with value -6.
    counts, val = exact_knapsack_min_py(
        np.array([-3.0, -2.0]), np.array([2.0, 1.0]), np.array([10.0, 1.0]), 4.0
    )
    assert val == -6.0
    np.testing.assert_allclose(counts, [2, 0])
    cj = np.asarray(
        bounded_knapsack_min(
            jnp.array([-3.0, -2.0]),
            jnp.array([2.0, 1.0]),
            jnp.array([10.0, 1.0]),
            jnp.asarray(4.0),
        )
    )
    assert float(np.dot([-3.0, -2.0], cj)) == -6.0
