"""Carbon-intensity source tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.carbon import (
    ConstantCarbonSource,
    RandomCarbonSource,
    TableCarbonSource,
    UKRegionalTraceSource,
    from_eso_csv,
    materialize,
)


def test_random_source_range_and_determinism():
    src = RandomCarbonSource(N=5, cmax=700)
    key = jax.random.PRNGKey(0)
    tab = materialize(src, 200, key)
    assert tab.shape == (200, 6)
    assert tab.min() >= 0 and tab.max() <= 700
    tab2 = materialize(src, 200, key)
    np.testing.assert_array_equal(tab, tab2)
    # different slots differ
    assert not np.array_equal(tab[0], tab[1])


def test_uk_trace_structure():
    src = UKRegionalTraceSource(N=5)
    tab = materialize(src, 48 * 7)  # one week of 30-min slots
    assert tab.shape == (48 * 7, 6)
    assert tab.min() >= 5.0 and tab.max() <= 700.0
    # regional identity: Scotland-like region (col 1) cleaner on average
    # than the gas-heavy region (col 2)
    assert tab[:, 1].mean() < tab[:, 2].mean()
    # diurnal structure: the mean slot-of-day profile has real amplitude
    prof = tab[:, 3].reshape(-1, 48).mean(0)
    assert prof.max() - prof.min() > 40.0


def test_uk_trace_deterministic_in_t():
    src = UKRegionalTraceSource(N=5, seed=7)
    k = jax.random.PRNGKey(99)  # source ignores the key: pure in (seed,t)
    a = src(jnp.asarray(13), k)
    b = src(jnp.asarray(13), jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]))


def test_table_source_wraps():
    tab = np.arange(12, dtype=np.float32).reshape(3, 4)
    src = TableCarbonSource(table=tab)
    Ce, Cc = src(jnp.asarray(4), None)  # t=4 -> row 1
    assert float(Ce) == tab[1, 0]
    np.testing.assert_array_equal(np.asarray(Cc), tab[1, 1:])
    assert src.N == 3


def test_eso_csv_loader(tmp_path):
    p = tmp_path / "eso.csv"
    p.write_text(
        "datetime,edge,r1,r2\n"
        "2022-01-01T00:00,100,50,300\n"
        "2022-01-01T00:30,120,60,280\n"
    )
    src = from_eso_csv(str(p), n_regions=2)
    Ce, Cc = src(jnp.asarray(1), None)
    assert float(Ce) == 120.0
    np.testing.assert_array_equal(np.asarray(Cc), [60.0, 280.0])


def test_eso_csv_header_only_raises(tmp_path):
    p = tmp_path / "empty.csv"
    p.write_text("datetime,edge,r1,r2\n")
    with pytest.raises(ValueError, match="no usable data rows"):
        from_eso_csv(str(p), n_regions=2)


def test_eso_csv_all_rows_malformed_raises_with_counts(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text(
        "datetime,edge,r1,r2\n"
        "2022-01-01T00:00,100\n"          # too few columns
        "2022-01-01T00:30,oops,60,280\n"  # non-numeric intensity
    )
    with pytest.raises(ValueError) as ei:
        from_eso_csv(str(p), n_regions=2)
    msg = str(ei.value)
    assert "skipped 2 malformed row(s)" in msg
    assert ">= 4" in msg  # expected column count spelled out


def test_eso_csv_skips_malformed_keeps_good(tmp_path):
    p = tmp_path / "mixed.csv"
    p.write_text(
        "datetime,edge,r1,r2\n"
        "2022-01-01T00:00,100,50,300\n"
        "short,row\n"
        "\n"
        "2022-01-01T00:30,120,60,280\n"
    )
    src = from_eso_csv(str(p), n_regions=2)
    assert src.table.shape == (2, 3)


def test_constant_source():
    src = ConstantCarbonSource(N=3, Ce=5.0, Cc=7.0)
    Ce, Cc = src(jnp.asarray(0), None)
    assert float(Ce) == 5.0
    assert np.all(np.asarray(Cc) == 7.0)


# ------------------------------------------- construction validation


def test_constant_source_validates_on_construction():
    with pytest.raises(ValueError, match="N >= 1"):
        ConstantCarbonSource(N=0)
    with pytest.raises(ValueError, match="scalar intensity"):
        ConstantCarbonSource(N=3, Ce=np.ones(3))
    with pytest.raises(ValueError, match=r"\[N=3\]"):
        ConstantCarbonSource(N=3, Cc=np.ones(4))
    # per-cloud Cc of the right length is legal
    src = ConstantCarbonSource(N=3, Cc=np.asarray([1.0, 2.0, 3.0]))
    _, Cc = src(jnp.asarray(0), None)
    np.testing.assert_array_equal(np.asarray(Cc), [1.0, 2.0, 3.0])


def test_table_source_validates_on_construction():
    with pytest.raises(ValueError, match="no shape"):
        TableCarbonSource(table=[[1.0, 2.0]])  # list has no .shape
    with pytest.raises(ValueError, match=r"\[T, N\+1\]"):
        TableCarbonSource(table=np.ones(5, np.float32))  # 1-D
    with pytest.raises(ValueError, match="at\n?.*least 1 row"):
        TableCarbonSource(table=np.ones((0, 3), np.float32))
    with pytest.raises(ValueError, match="2 columns"):
        TableCarbonSource(table=np.ones((4, 1), np.float32))


def test_table_source_accepts_traced_tables():
    """simulate_fleet builds one source per vmapped lane with a TRACED
    table slab -- shape-only validation must not read values."""
    def f(tab):
        src = TableCarbonSource(table=tab)
        Ce, Cc = src(jnp.asarray(1), None)
        return Ce + jnp.sum(Cc)

    out = jax.jit(f)(jnp.ones((4, 3), jnp.float32))
    assert float(out) == 3.0
