"""Unit + property tests for the virtual queueing network (paper §III)."""
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skips

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis.extra import numpy as hnp

from repro.core import queueing as Q

jax.config.update("jax_enable_x64", False)


def small_spec(M=3, N=2):
    return Q.NetworkSpec(
        pe=np.array([2.0, 3.0, 5.0][:M], np.float32),
        pc=np.arange(1, M * N + 1, dtype=np.float32).reshape(M, N) * 3.0,
        Pe=50.0,
        Pc=np.full((N,), 100.0, np.float32),
    )


def test_step_matches_equations_7_8():
    spec = small_spec()
    state = Q.NetworkState(
        Qe=jnp.array([5.0, 0.0, 2.0]),
        Qc=jnp.array([[1.0, 0.0], [4.0, 2.0], [0.0, 0.0]]),
    )
    d = jnp.array([[2.0, 1.0], [0.0, 0.0], [3.0, 0.0]])
    w = jnp.array([[1.0, 0.0], [5.0, 1.0], [0.0, 0.0]])
    a = jnp.array([1.0, 2.0, 0.0])
    nxt = Q.step(state, Q.Action(d, w), a)
    # eq (7): max(Qe - sum_n d, 0) + a
    np.testing.assert_allclose(
        np.asarray(nxt.Qe), [max(5 - 3, 0) + 1, 0 + 2, max(2 - 3, 0) + 0]
    )
    # eq (8): max(Qc - w, 0) + d
    np.testing.assert_allclose(
        np.asarray(nxt.Qc),
        [[max(1 - 1, 0) + 2, 0 + 1], [max(4 - 5, 0) + 0, max(2 - 1, 0)], [3, 0]],
    )


def test_emissions_eq5():
    spec = small_spec()
    d = jnp.ones((3, 2))
    w = jnp.ones((3, 2)) * 2
    Ce, Cc = jnp.float32(10.0), jnp.array([1.0, 2.0])
    got = Q.emissions(spec, Q.Action(d, w), Ce, Cc)
    pe_total = float(np.sum(np.asarray(spec.pe)[:, None] * np.asarray(d)))
    pc_total = np.sum(np.asarray(spec.pc) * np.asarray(w), axis=0)
    want = 10.0 * pe_total + np.dot([1.0, 2.0], pc_total)
    np.testing.assert_allclose(float(got), want, rtol=1e-6)


def test_feasibility_checks():
    spec = small_spec()
    ok = Q.Action(d=jnp.zeros((3, 2)), w=jnp.zeros((3, 2)))
    assert bool(Q.is_feasible(spec, ok))
    too_much_edge = Q.Action(d=jnp.full((3, 2), 100.0), w=jnp.zeros((3, 2)))
    assert not bool(Q.is_feasible(spec, too_much_edge))
    fractional = Q.Action(d=jnp.full((3, 2), 0.5), w=jnp.zeros((3, 2)))
    assert not bool(Q.is_feasible(spec, fractional))
    negative = Q.Action(d=jnp.zeros((3, 2)), w=-jnp.ones((3, 2)))
    assert not bool(Q.is_feasible(spec, negative))


@given(
    Qe=hnp.arrays(np.float32, (3,), elements=st.integers(0, 50).map(float)),
    Qc=hnp.arrays(np.float32, (3, 2), elements=st.integers(0, 50).map(float)),
    d=hnp.arrays(np.float32, (3, 2), elements=st.integers(0, 20).map(float)),
    w=hnp.arrays(np.float32, (3, 2), elements=st.integers(0, 20).map(float)),
    a=hnp.arrays(np.float32, (3,), elements=st.integers(0, 20).map(float)),
)
@settings(max_examples=50, deadline=None)
def test_queues_stay_nonnegative_and_integral(Qe, Qc, d, w, a):
    state = Q.NetworkState(Qe=jnp.asarray(Qe), Qc=jnp.asarray(Qc))
    nxt = Q.step(state, Q.Action(jnp.asarray(d), jnp.asarray(w)), jnp.asarray(a))
    assert np.all(np.asarray(nxt.Qe) >= 0)
    assert np.all(np.asarray(nxt.Qc) >= 0)
    assert np.all(np.asarray(nxt.Qe) == np.round(np.asarray(nxt.Qe)))
    assert np.all(np.asarray(nxt.Qc) == np.round(np.asarray(nxt.Qc)))


def test_lyapunov_eq15():
    state = Q.NetworkState(
        Qe=jnp.array([3.0, 4.0]), Qc=jnp.array([[1.0], [2.0]])
    )
    assert float(Q.lyapunov(state)) == 0.5 * (9 + 16 + 1 + 4)


def test_drift_bound_B_dominates_realized_terms(rng):
    """B from (18): 2B >= sum a^2 + sum(sum_n d)^2 + sum d^2 + sum w^2 for
    any feasible action and bounded arrivals."""
    spec = small_spec()
    B = float(Q.drift_bound_B(spec, a_max=np.full(3, 10.0)))
    for _ in range(200):
        a = rng.integers(0, 11, 3).astype(float)
        # random feasible action via rejection
        d = rng.integers(0, 5, (3, 2)).astype(float)
        w = rng.integers(0, 5, (3, 2)).astype(float)
        if float(Q.edge_energy(jnp.asarray(spec.pe), jnp.asarray(d))) > spec.Pe:
            continue
        if np.any(
            np.asarray(Q.cloud_energy(jnp.asarray(spec.pc), jnp.asarray(w)))
            > np.asarray(spec.Pc)
        ):
            continue
        lhs = (
            np.sum(a**2)
            + np.sum(d.sum(1) ** 2)
            + np.sum(d**2)
            + np.sum(w**2)
        )
        assert lhs <= 2 * B + 1e-5
