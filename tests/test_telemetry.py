"""Telemetry layer tests (repro.telemetry).

The standing anchors:

* telemetry=None is bitwise-identical to the pre-telemetry simulators
  on every variant (plain / WAN / faulted / WAN-faulted) and both score
  backends -- the tap carry is `()` (zero pytree leaves) so the traced
  program is the same program;
* turning the taps ON never perturbs the base trajectory -- every
  non-telemetry result field stays bitwise equal;
* the whole Telemetry frame is bitwise equal across the three record
  modes (series ride the per-slot scalar path, gauges/alerts are
  reductions of the series);
* the conservation monitor holds an exact zero residual on all four
  simulators (it is the check that caught the step_links negative-
  delivery leak this layer shipped with a fix for);
* each SLO monitor trips exactly where hand-built probe sequences and
  deterministic fault scenarios say it must;
* all three exporters emit output their own validators accept, and
  `oracle_gap_series` agrees with `oracle_emissions_horizon`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import fleet_scenarios
from repro.configs.fleet_scenarios import build_fleet
from repro.core import (
    CarbonIntensityPolicy,
    RandomCarbonSource,
    TableCarbonSource,
    UniformArrivals,
    oracle_emissions_horizon,
    simulate,
    simulate_fleet,
)
from repro.core.carbon import diurnal_table
from repro.faults import make_faults
from repro.network import NetworkAwareDPPPolicy, star_graph
from repro.telemetry import (
    MONITORS,
    TelemetryConfig,
    TelemetryProbe,
    finalize_taps,
    init_taps,
    lane,
    manifest,
    oracle_gap_series,
    step_taps,
    to_chrome_trace,
    to_jsonl,
    to_prometheus,
    validate_chrome_trace,
    validate_dir,
    validate_jsonl,
    validate_prometheus,
    write_run,
)

jax.config.update("jax_enable_x64", False)

T = 48
M, N = 4, 3
CFG = TelemetryConfig()
KINDS = ["plain", "wan", "faulted", "wan-faulted"]
K = len(MONITORS)


def _setup():
    spec = fleet_scenarios._base(M, N)
    return (
        spec,
        RandomCarbonSource(N=N),
        UniformArrivals(M=M),
        jax.random.PRNGKey(42),
    )


def _run(kind, backend="reference", telemetry=None, record="full"):
    """One simulation per simulator variant, telemetry on or off."""
    spec, src, arr, key = _setup()
    interp = True if backend == "pallas" else None
    kw = {}
    if kind in ("wan", "wan-faulted"):
        pol = NetworkAwareDPPPolicy(
            V=0.05, score_backend=backend, score_interpret=interp
        )
        kw["graph"] = star_graph(M, N, np.random.default_rng(7))
        if kind == "wan-faulted":
            kw["faults"] = make_faults(
                N, kw["graph"].L, task_p_fail=0.1,
                link_p_down=0.2, link_p_up=0.5, link_floor=0.0,
            )
    else:
        pol = CarbonIntensityPolicy(
            V=0.05, score_backend=backend, score_interpret=interp
        )
        if kind == "faulted":
            kw["faults"] = make_faults(
                N, task_p_fail=0.1, cloud_p_down=0.1, cloud_p_up=0.5,
                telem_p_down=0.1, telem_p_up=0.5,
            )
    return simulate(pol, spec, src, arr, T, key,
                    telemetry=telemetry, record=record, **kw)


def _assert_frames_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------------------------------------------- parity anchors


def test_telemetry_defaults_to_none():
    res = _run("plain")
    assert res.telemetry is None
    fleet = build_fleet(["diurnal-slack"], per_kind=1, M=M, N=N,
                        Tc=24, seed=0)
    fres = simulate_fleet(CarbonIntensityPolicy(), fleet, 12,
                          jax.random.PRNGKey(0), record="summary")
    assert fres.telemetry is None


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("kind", KINDS)
def test_taps_on_leaves_base_fields_bitwise(kind, backend):
    """The taps observe, never steer: with telemetry=CFG every field
    the telemetry=None result also carries is bitwise unchanged."""
    r0 = _run(kind, backend)
    r1 = _run(kind, backend, telemetry=CFG)
    assert r0.telemetry is None and r1.telemetry is not None
    for name in type(r0)._fields:
        if name == "telemetry":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(r0, name)),
            np.asarray(getattr(r1, name)),
            err_msg=f"{kind}/{backend}: {name}",
        )


@pytest.mark.parametrize("kind", ["faulted", "wan"])
def test_frame_bitwise_equal_across_record_modes(kind):
    """TapSeries rides the per-slot scalar path, and every gauge/alert
    is a reduction of a series -- so the WHOLE frame is record-mode
    independent, bit for bit."""
    full = _run(kind, telemetry=CFG, record="full").telemetry
    summ = _run(kind, telemetry=CFG, record="summary").telemetry
    strd = _run(kind, telemetry=CFG, record=4).telemetry
    _assert_frames_equal(full, summ)
    _assert_frames_equal(full, strd)


@pytest.mark.parametrize("kind", KINDS)
def test_conservation_residual_exactly_zero(kind):
    """Task conservation (arrived == backlog + processed - failed,
    in-flight included) holds to an exact 0.0 in float32 on every
    simulator -- integral counts, exact f32 arithmetic."""
    tel = _run(kind, telemetry=CFG).telemetry
    assert float(np.abs(np.asarray(tel.conservation_residual)).max()) \
        == 0.0
    k = MONITORS.index("conservation_drift")
    assert int(np.asarray(tel.alert_tripped)[k]) == 0
    assert int(np.asarray(tel.alert_first_slot)[k]) == -1


# ------------------------------------------------------------ tap math


def _probe(backlog=0.0, arrived=0.0, processed=0.0, failed=0.0,
           stale=0, clouds_down=0.0):
    return TelemetryProbe(
        emissions=jnp.float32(1.0),
        arrived=jnp.float32(arrived),
        dispatched=jnp.zeros((N,), jnp.float32),
        processed=jnp.float32(processed),
        failed=jnp.float32(failed),
        wasted=jnp.float32(0.0),
        backlog=jnp.float32(backlog),
        stale=jnp.int32(stale),
        clouds_down=jnp.float32(clouds_down),
        retry_depth=jnp.float32(0.0),
        transfer_occupancy=jnp.float32(0.0),
    )


def _run_taps(cfg, probes):
    tap = init_taps()
    rows = []
    for p in probes:
        tap, row = step_taps(cfg, tap, p)
        rows.append(row)
    series = jax.tree.map(lambda *xs: jnp.stack(xs), *rows)
    return finalize_taps(cfg, series)


def _alert(tel, monitor):
    k = MONITORS.index(monitor)
    return (
        int(np.asarray(tel.alert_tripped)[k]),
        int(np.asarray(tel.alert_first_slot)[k]),
        int(np.asarray(tel.alert_count)[k]),
    )


def test_backlog_growth_monitor_needs_sustained_growth():
    cfg = dataclasses.replace(CFG, growth_sustain=3)
    # backlog 1,2,3,... grows every slot: the run counter reaches 3 at
    # slot index 2 and never resets.
    tel = _run_taps(cfg, [_probe(backlog=float(i + 1), arrived=1.0)
                          for i in range(8)])
    assert _alert(tel, "backlog_growth") == (1, 2, 6)
    # a flat slot resets the run: 1,2,2,3,4,5 re-arms at slot 3 and
    # only reaches 3 consecutive growth slots at slot 5.
    levels = [1.0, 2.0, 2.0, 3.0, 4.0, 5.0]
    deltas = [levels[0]] + [b - a for a, b in zip(levels, levels[1:])]
    tel = _run_taps(cfg, [_probe(backlog=b, arrived=d)
                          for b, d in zip(levels, deltas)])
    assert _alert(tel, "backlog_growth") == (1, 5, 1)


def test_staleness_monitor_threshold():
    tel = _run_taps(CFG, [_probe(stale=i) for i in range(10)])
    # trips strictly beyond the guard budget: stale=5 at slot 5
    assert _alert(tel, "signal_staleness") == (1, CFG.stale_budget + 1,
                                               10 - CFG.stale_budget - 1)
    tel = _run_taps(CFG, [_probe(stale=CFG.stale_budget)] * 6)
    assert _alert(tel, "signal_staleness") == (0, -1, 0)


def test_all_clouds_down_monitor():
    probes = [_probe(clouds_down=float(N - 1))] * 3 \
        + [_probe(clouds_down=float(N))] * 2
    tel = _run_taps(CFG, probes)
    assert _alert(tel, "all_clouds_down") == (1, 3, 2)


def test_conservation_drift_monitor():
    # one arrival per slot that lands nowhere: residual 1, 2, 3, ...
    tel = _run_taps(CFG, [_probe(arrived=1.0)] * 4)
    assert _alert(tel, "conservation_drift") == (1, 0, 4)
    # balanced books: arrivals either backlogged or processed
    tel = _run_taps(CFG, [
        _probe(arrived=2.0, backlog=1.0, processed=1.0),
        _probe(arrived=2.0, backlog=2.0, processed=1.0),
    ])
    assert _alert(tel, "conservation_drift") == (0, -1, 0)


# ------------------------------------------ monitors on real fault runs


def test_staleness_trips_under_dead_carbon_feed():
    """telem_p_down=1 kills the feed at slot 0; staleness then grows
    past any budget and the monitor reports the exact first slot."""
    spec, src, arr, key = _setup()
    cfg = dataclasses.replace(CFG, stale_budget=2)
    res = simulate(
        CarbonIntensityPolicy(V=0.05), spec, src, arr, T, key,
        faults=make_faults(N, telem_p_down=1.0, telem_p_up=0.0),
        telemetry=cfg,
    )
    # stale = 1, 2, 3, ... from slot 0; first stale > 2 is slot 2
    assert _alert(res.telemetry, "signal_staleness") == (1, 2, T - 2)
    np.testing.assert_array_equal(
        np.asarray(res.telemetry.staleness), np.arange(1, T + 1)
    )


def test_all_clouds_down_trips_under_total_blackout():
    spec, src, arr, key = _setup()
    res = simulate(
        CarbonIntensityPolicy(V=0.05), spec, src, arr, T, key,
        faults=make_faults(N, sched_start=0.0, sched_len=float(T)),
        telemetry=CFG,
    )
    assert _alert(res.telemetry, "all_clouds_down") == (1, 0, T)
    assert float(np.asarray(res.telemetry.clouds_down).min()) == N


# --------------------------------------------------------------- fleets


def test_fleet_frame_vmaps_and_lane_matches_solo():
    """simulate_fleet stacks a whole Telemetry frame per lane; one lane
    of it equals a solo simulate of that lane's scenario."""
    fleet = build_fleet(["diurnal-slack"], per_kind=2, M=M, N=N,
                        Tc=24, seed=0)
    res = simulate_fleet(CarbonIntensityPolicy(), fleet, T,
                         jax.random.PRNGKey(0), record="summary",
                         telemetry=CFG)
    tel = res.telemetry
    assert np.asarray(tel.peak_backlog).shape == (fleet.F,)
    assert np.asarray(tel.backlog).shape == (fleet.F, T)
    assert np.asarray(tel.alert_active).shape == (fleet.F, T, K)
    assert np.asarray(tel.alert_first_slot).shape == (fleet.F, K)
    l0 = lane(tel, 0)
    assert np.asarray(l0.peak_backlog).shape == ()
    assert np.asarray(l0.backlog).shape == (T,)
    man = manifest(tel)
    assert man["peak_backlog"] == float(np.asarray(tel.peak_backlog).max())
    assert set(man["alerts"]) == set(MONITORS)


# ------------------------------------------------------------- exporters


@pytest.fixture(scope="module")
def frame():
    return _run("faulted", telemetry=CFG).telemetry


def test_exporters_roundtrip_their_validators(frame):
    assert validate_prometheus(to_prometheus(frame)) > 10
    assert validate_jsonl(to_jsonl(frame)) >= T + 1
    assert validate_chrome_trace(to_chrome_trace(frame)) > T


def test_exporters_reject_fleet_frames(frame):
    fleet_frame = jax.tree.map(lambda x: jnp.stack([x, x]), frame)
    with pytest.raises(ValueError, match="lane"):
        to_prometheus(fleet_frame)
    # the fleet path is manifest(), which must accept it
    assert manifest(fleet_frame)["alerts"]


def test_write_run_and_validate_dir(frame, tmp_path):
    paths = write_run(frame, tmp_path, stem="t")
    counts = validate_dir(tmp_path)
    assert set(map(str, paths.values())) == set(counts)
    with pytest.raises(ValueError, match="no .*files"):
        validate_dir(tmp_path / "empty")


def test_validators_reject_garbage():
    with pytest.raises(ValueError):
        validate_prometheus("repro_thing 1.0\n")  # sample before TYPE
    with pytest.raises(ValueError):
        validate_jsonl('{"event": "slot"}\n')     # no summary
    with pytest.raises(ValueError):
        validate_chrome_trace('{"traceEvents": []}')


def test_jsonl_slot_events_carry_the_series(frame):
    import json

    lines = [json.loads(x) for x in to_jsonl(frame).splitlines()]
    slots = [ev for ev in lines if ev["event"] == "slot"]
    assert len(slots) == T
    em = np.asarray(frame.emission_rate)
    for t in (0, T // 2, T - 1):
        assert slots[t]["emission_rate"] == pytest.approx(float(em[t]))
        assert len(slots[t]["dispatched_cloud"]) == N


# ------------------------------------------------------------ oracle gap


def test_oracle_gap_series_matches_horizon_bound():
    """oracle_gap_series is the per-slot refinement of
    oracle_emissions_horizon: same windowed-min repricing, so the sums
    agree; and at H=1 the oracle is the realized cost (gap ~ 0)."""
    spec, _, arr, key = _setup()
    tab = diurnal_table(T, N, np.random.default_rng(3))
    res = simulate(
        CarbonIntensityPolicy(V=0.05), spec, TableCarbonSource(tab),
        arr, T, key, telemetry=CFG,
    )
    ee = np.asarray(res.energy_edge, np.float64)
    ec = np.asarray(res.energy_cloud, np.float64)
    for horizon in (1, 8, None):
        oracle, gap = oracle_gap_series(res, tab, horizon=horizon)
        bound = oracle_emissions_horizon(tab, ee, ec, horizon=horizon)
        assert float(oracle.sum()) == pytest.approx(bound, rel=1e-5)
        np.testing.assert_allclose(
            np.asarray(res.emissions), oracle + gap, rtol=1e-5
        )
    oracle1, gap1 = oracle_gap_series(res, tab, horizon=1)
    assert float(np.abs(gap1).max()) <= 1e-3 * max(
        1.0, float(np.abs(np.asarray(res.emissions)).max())
    )
    # longer windows only cheapen the oracle, slot by slot
    oracle8, _ = oracle_gap_series(res, tab, horizon=8)
    assert np.all(oracle8 <= oracle1 + 1e-6)
