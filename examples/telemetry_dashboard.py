"""Watching a run: the telemetry layer end to end.

A telemetry-brownout fleet (carbon feed dropping in and out) runs with
the in-scan metrics taps on; the script then

  * prints a small per-lane dashboard -- run gauges, the SLO alert
    record (which monitors tripped, when, for how long), and a sparkline
    of the backlog and emission-rate series;
  * re-prices lane 0's energy profile against the clairvoyant windowed
    oracle (`oracle_gap_series`) to show where the policy paid carbon
    the oracle would not have;
  * exports lane 0 in all three wire formats (Prometheus text,
    JSON-lines events, Chrome trace) to artifacts/telemetry/ and
    re-validates every file -- the same gate CI's telemetry-smoke job
    runs.

    PYTHONPATH=src python examples/telemetry_dashboard.py

Load the .trace.json in Perfetto / chrome://tracing for the series and
alert-window tracks; scrape the .prom file with any Prometheus agent.
"""
import os
from pathlib import Path

import jax
import numpy as np

from repro.configs.fleet_scenarios import build_fleet, with_faults
from repro.core import CarbonIntensityPolicy, simulate_fleet
from repro.faults import StalenessGuardPolicy
from repro.telemetry import (
    MONITORS,
    TelemetryConfig,
    lane,
    manifest,
    oracle_gap_series,
    validate_dir,
    write_run,
)

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI telemetry-smoke job
PER_KIND = 2 if SMOKE else 8
T = 48 if SMOKE else 192
OUT = Path(__file__).resolve().parents[1] / "artifacts" / "telemetry"

BARS = " .:-=+*#%@"


def spark(xs: np.ndarray, width: int = 48) -> str:
    xs = np.asarray(xs, np.float64)
    if xs.size > width:
        xs = xs[: xs.size - xs.size % width].reshape(width, -1).mean(1)
    lo, hi = float(xs.min()), float(xs.max())
    span = (hi - lo) or 1.0
    idx = ((xs - lo) / span * (len(BARS) - 1)).astype(int)
    return "".join(BARS[i] for i in idx)


def main() -> None:
    fleet = with_faults(
        build_fleet(["diurnal-slack"], per_kind=PER_KIND, Tc=96, seed=0),
        "telemetry-brownout",
    )
    cfg = TelemetryConfig(stale_budget=3)
    pol = StalenessGuardPolicy(inner=CarbonIntensityPolicy(V=0.05))
    res = simulate_fleet(
        pol, fleet, T, jax.random.PRNGKey(0), record="summary",
        telemetry=cfg,
    )
    tel = res.telemetry
    print(f"telemetry-brownout: {fleet.F} lanes x T={T} slots, "
          f"guard(carbon) with taps on\n")

    man = manifest(tel)
    print(f"fleet manifest: peak backlog {man['peak_backlog']:.0f}, "
          f"emissions {man['total_emissions']:.3e}, "
          f"wasted {man['total_wasted']:.3e}")
    for mon in MONITORS:
        a = man["alerts"][mon]
        state = (
            f"TRIPPED on {a['tripped']} lane(s), "
            f"{a['slots_active']} firing slots, "
            f"first at slot {a['first_slot']}"
            if a["tripped"] else "clear"
        )
        print(f"  {mon:18s} {state}")

    l0 = lane(tel, 0)
    print("\nlane 0:")
    print(f"  backlog       {spark(np.asarray(l0.backlog))}  "
          f"peak {float(np.asarray(l0.peak_backlog)):.0f}")
    print(f"  emission rate {spark(np.asarray(l0.emission_rate))}")
    print(f"  staleness     {spark(np.asarray(l0.staleness))}  "
          f"max {int(np.asarray(l0.staleness).max())} slots "
          f"(budget {cfg.stale_budget})")

    # clairvoyant re-pricing of lane 0's energy profile
    tab = np.asarray(fleet.carbon[0])
    oracle, gap = oracle_gap_series(lane_result(res, 0), tab, horizon=24)
    frac = float(gap.sum()) / max(float(oracle.sum() + gap.sum()), 1e-9)
    print(f"  oracle gap    {spark(gap)}  "
          f"{100.0 * frac:.1f}% of emissions above the H=24 oracle")

    paths = write_run(l0, OUT, stem="brownout_lane0")
    counts = validate_dir(OUT)
    print(f"\nwrote {len(paths)} files to {OUT}:")
    for p, n in sorted(counts.items()):
        print(f"  {p}  ({n} samples/events, validated)")


def lane_result(res, i):
    """One lane of a fleet SimResult (the exporters' per-lane view)."""
    return type(res)(*[
        None if x is None else jax.tree.map(lambda v: v[i], x)
        for x in res
    ])


if __name__ == "__main__":
    main()
