"""End-to-end driver: carbon-aware orchestration of REAL training jobs.

Two LM training jobs (reduced qwen1.5 and internlm2 configs, ~a few M
params each on CPU; the same code paths drive the full configs on a pod)
run under the GreenOrchestrator: the paper's drift-plus-penalty policy
decides, slot by slot, when and on which "cloud" each training task
executes, based on live (synthetic UK-regional) carbon intensity.

Demonstrates: a few hundred real optimizer steps, emission accounting,
checkpoint/restart (kill and re-run the script -- it resumes), and a
mid-run simulated cloud failure with automatic re-routing.

    PYTHONPATH=src python examples/train_carbon_aware.py
"""
import os
import jax
import numpy as np

from repro.configs import registry
from repro.core.carbon import UKRegionalTraceSource
from repro.core.policies import CarbonIntensityPolicy
from repro.core.queueing import NetworkSpec
from repro.data.pipeline import make_batch_fn
from repro.models import build_model
from repro.optim.adamw import AdamW, cosine_schedule, make_train_step
from repro.orchestrator.green import Cloud, GreenOrchestrator, TrainJob

CKPT_DIR = "/tmp/repro_green_ckpt"
SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
N_SLOTS = 6 if SMOKE else 40
STEPS_PER_TASK = 4  # each scheduled task = 4 real optimizer steps


def make_jobs():
    jobs = []
    for i, aid in enumerate(["qwen1_5_0_5b", "internlm2_20b"]):
        cfg = registry.get_smoke_config(aid)
        model = build_model(cfg)
        opt = AdamW(lr=cosine_schedule(1e-3, 20, 400))
        params = model.init(jax.random.PRNGKey(i))
        jobs.append(TrainJob(
            name=aid,
            model=model,
            train_step=jax.jit(make_train_step(model, opt)),
            batch_fn=make_batch_fn(cfg, seq_len=128, global_batch=4, seed=i),
            params=params,
            opt_state=opt.init(params),
            steps_per_task=STEPS_PER_TASK,
        ))
    return jobs


def arrivals(t):
    rng = np.random.default_rng((42, t))
    return rng.integers(0, 3, 2).astype(np.float32)


def main():
    spec = NetworkSpec(
        pe=np.asarray([0.5, 0.8], np.float32),
        pc=np.asarray([[4.0, 4.0], [7.0, 7.0]], np.float32),
        Pe=6.0,
        Pc=np.asarray([16.0, 16.0], np.float32),
    )
    orch = GreenOrchestrator(
        jobs=make_jobs(),
        clouds=[Cloud("eu-north"), Cloud("uk-south")],
        spec=spec,
        carbon_source=UKRegionalTraceSource(N=2),
        arrival_fn=arrivals,
        policy=CarbonIntensityPolicy(V=0.01),
        ckpt_dir=CKPT_DIR,
        ckpt_every=5,
        max_tasks_per_slot=2,
    )
    if orch.resume():
        print(f"resumed from slot {orch.t} "
              f"(cum emissions {orch.cum_emissions:.1f})")

    while orch.t < N_SLOTS:
        slot = orch.t
        if slot == 20:
            orch.fail_cloud(1)
            print("  !! cloud uk-south failed; policy re-routes to eu-north")
        if slot == 30:
            orch.join_cloud(1)
            print("  !! cloud uk-south rejoined")
        h = orch.run_slot()
        losses = {k: f"{v:.3f}" for k, v in h.items() if k.startswith("loss")}
        print(f"slot {slot:3d} emissions {h['emissions']:8.1f} "
              f"backlog {h['backlog']:5.0f} executed {h['executed']:4d} "
              f"{losses}")
    if orch.ckpt:
        orch.checkpoint()
        orch.ckpt.wait()

    print(f"\ntotal steps trained: "
          f"{ {j.name: j.step for j in orch.jobs} }")
    print(f"cumulative emissions: {orch.cum_emissions:.1f} gCO2-eq")
    for j in orch.jobs:
        if len(j.losses) >= 2:
            print(f"  {j.name}: loss {j.losses[0]:.3f} -> {j.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
