"""Deadline/SLO-aware scheduling: the emission-vs-miss-vs-waiting
Pareto on the diurnal-slack fleet, then graceful shedding under
engineered overload.

    PYTHONPATH=src python examples/deadline_pareto.py

Part 1 attaches generous per-type deadlines (generous-slack scenario)
and compares the deadline-aware policies against the unconstrained
LookaheadDPP schedule: the slack-threshold policy should match its
emission reduction at zero misses (urgency never fires while slack is
wide), WaitAwhile trades a little reduction for tighter waiting, and
carbon-blind EDD shows what ignoring carbon costs. Part 2 switches to
the overload arrival scenario with tight deadlines: unshedded, tasks
expire; with admission control (shed-overload scenario) the same
policy sheds at the door and holds misses at zero.
"""
import os
import time

import jax
import numpy as np

from repro.configs.fleet_scenarios import build_fleet, with_deadlines
from repro.core import (
    CarbonIntensityPolicy,
    LookaheadDPPPolicy,
    simulate_fleet,
)
from repro.deadlines import (
    EDDPolicy,
    SlackThresholdPolicy,
    WaitAwhilePolicy,
)
from repro.forecast import ClairvoyantTableForecaster

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
PER_KIND = 2 if SMOKE else 16
T = 24 if SMOKE else 192
H = 4 if SMOKE else 16
V = 0.2


def run(pol, fleet, key, forecaster=None):
    f = jax.jit(lambda: simulate_fleet(
        pol, fleet, T, key, forecaster=forecaster, record="summary"
    ))
    f().cum_emissions.block_until_ready()  # compile
    t0 = time.perf_counter()
    r = f()
    r.cum_emissions.block_until_ready()
    return r, (time.perf_counter() - t0) * 1e6 / (fleet.F * T)


def main() -> None:
    key = jax.random.PRNGKey(0)
    fleet = build_fleet(["diurnal-slack"], per_kind=PER_KIND, Tc=96,
                        seed=0)
    fc = ClairvoyantTableForecaster(H=H)
    print(f"deadline Pareto: {fleet.F} lanes x T={T} slots")

    r_base, _ = run(CarbonIntensityPolicy(V=V), fleet, key)
    em_base = np.asarray(r_base.cum_emissions[:, -1])
    r_la, _ = run(LookaheadDPPPolicy(V=V, H=H), fleet, key,
                  forecaster=fc)

    def red(r):
        return float(
            100.0 * (1.0 - np.asarray(r.cum_emissions[:, -1]) / em_base
                     ).mean()
        )

    print(f"  lookahead H={H} (no deadlines)  "
          f"reduction {red(r_la):5.1f}%  (the target schedule)")

    slack = with_deadlines(fleet, "generous-slack")
    for name, pol, fcast in [
        ("slack-threshold", SlackThresholdPolicy(V=V, H=H), fc),
        ("wait-awhile J=2", WaitAwhilePolicy(V=V, H=H, J=2), fc),
        ("EDD (carbon-blind)", EDDPolicy(), None),
    ]:
        r, us = run(pol, slack, key, forecaster=fcast)
        led = r.deadlines
        missed = float(np.asarray(led.missed).sum())
        admitted = float(np.asarray(led.admitted).sum())
        print(
            f"  {name:<18} reduction {red(r):7.1f}%  "
            f"missed {missed:.0f}/{admitted:.0f}  ({us:.1f} us/lane-slot)"
        )

    over = build_fleet(["overload"], per_kind=PER_KIND, Tc=96, seed=0)
    pol = SlackThresholdPolicy(V=V)
    print(f"overload shedding: {over.F} lanes x T={T} slots")
    for name, kind in [
        ("tight, unshedded ", "tight-uniform"),
        ("admission control", "shed-overload"),
    ]:
        r, us = run(pol, with_deadlines(over, kind), key)
        led = r.deadlines
        missed = float(np.asarray(led.missed).sum())
        shed = float(np.asarray(led.shed).sum())
        offered = float(np.asarray(led.admitted).sum()) + shed
        print(
            f"  {name} missed {100.0 * missed / offered:5.2f}%  "
            f"shed {100.0 * shed / offered:5.2f}% of offered load"
        )


if __name__ == "__main__":
    main()
