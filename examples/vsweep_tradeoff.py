"""The Theorem-1 tradeoff curve (Figs. 2+4 combined) in one compiled call:
simulate_vsweep vmaps the ENTIRE network simulation over a vector of V
values -- emissions fall as O(1/V), queues grow as O(V).

    PYTHONPATH=src python examples/vsweep_tradeoff.py
"""
import os
import jax

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_workloads import paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UniformArrivals,
    simulate,
    simulate_vsweep,
)


def spark(vals, width=40):
    vals = np.asarray(vals, float)
    lo, hi = vals.min(), vals.max()
    chars = " .:-=+*#%@"
    idx = ((vals - lo) / max(hi - lo, 1e-9) * (len(chars) - 1)).astype(int)
    return "".join(chars[i] for i in idx[:width])


def main():
    spec = paper_spec()
    carbon = RandomCarbonSource(N=5)
    arrive = UniformArrivals(M=5, amax=400)
    key = jax.random.PRNGKey(0)
    T = 60 if SMOKE else 2000
    Vs = jnp.asarray([0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5])

    res = jax.jit(lambda: simulate_vsweep(
        lambda V: CarbonIntensityPolicy(V=V), Vs, spec, carbon, arrive, T,
        key,
    ))()
    base = float(jax.jit(lambda: simulate(
        QueueLengthPolicy(), spec, carbon, arrive, T, key
    ).cum_emissions[-1])())

    print(f"{'V':>8} {'emission reduction':>20} {'mean edge queue':>16}")
    for i, v in enumerate(np.asarray(Vs)):
        red = 100 * (1 - float(res.cum_emissions[i, -1]) / base)
        q = float(res.Qe[i].mean())
        print(f"{v:8.3f} {red:19.1f}% {q:16.1f}")

    print("\ncumulative-emission trajectories (low V -> high V):")
    for i in (0, 3, 5, 7):
        tr = np.asarray(res.cum_emissions[i])[:: T // 40]
        print(f"  V={float(Vs[i]):5.3f}  {spark(tr)}")
    print("\nTheorem 1: emissions gap ~ B/V; queue growth ~ O(V). Pick V "
          "to trade carbon for latency.")


if __name__ == "__main__":
    main()
