"""Forecast + receding-horizon walkthrough: how much does seeing the
future (imperfectly) cut emissions?

    PYTHONPATH=src python examples/forecast_lookahead.py

Three acts:
  1. Forecast quality -- roll every forecaster over a diurnal trace and
     score MAE on leads 1..H-1 (persistence is the bar to clear).
  2. Lookahead vs myopic -- LookaheadDPPPolicy on the diurnal-slack
     fleet scenario with perfect, noisy, and learned forecasts; H=1
     reproduces the myopic policy exactly.
  3. The sandwich -- the clairvoyant-horizon oracle lower-bounds what
     ANY H-slot planner could emit for the same energy profile, so you
     can see how much of the available lookahead value the policy
     captures.
"""
import os
import jax
import numpy as np

from repro.configs.fleet_scenarios import build_fleet
from repro.configs.paper_workloads import paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    LookaheadDPPPolicy,
    TableCarbonSource,
    UniformArrivals,
    diurnal_table,
    oracle_emissions_horizon,
    simulate,
    simulate_fleet,
)
from repro.forecast import (
    ClairvoyantTableForecaster,
    EWMAForecaster,
    ForecastErrorModel,
    PersistenceForecaster,
    RidgeARForecaster,
    SeasonalNaiveForecaster,
    forecast_errors,
)

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
H, T, V = 8, (48 if SMOKE else 192), 0.2


def act1_forecast_quality(tab):
    print("== 1. forecast quality on a diurnal trace "
          f"(MAE over leads 1..{H - 1}, lower is better) ==")
    for name, fc in [
        ("persistence", PersistenceForecaster(H=H)),
        ("seasonal-naive", SeasonalNaiveForecaster(H=H, period=48)),
        ("ewma", EWMAForecaster(H=H)),
        ("ridge-AR", RidgeARForecaster(H=H)),
    ]:
        err = forecast_errors(fc, tab, burn_in=64)
        lead = np.asarray(err["mae_per_lead"])
        print(f"  {name:<15} mae={float(err['mae']):7.1f}   "
              f"lead1={lead[0]:6.1f}  lead{H - 1}={lead[-1]:6.1f}")


def act2_lookahead_vs_myopic():
    print("\n== 2. lookahead vs myopic on the diurnal-slack fleet "
          f"(F=16, T={T}, V={V}) ==")
    fleet = build_fleet(["diurnal-slack"], per_kind=2 if SMOKE else 16,
                        Tc=96, seed=0)
    key = jax.random.PRNGKey(0)

    def run(policy, forecaster=None):
        res = jax.jit(lambda: simulate_fleet(
            policy, fleet, T, key, forecaster=forecaster
        ))()
        em = np.asarray(res.cum_emissions[:, -1])
        bl = np.asarray(res.Qe[:, -1].sum(-1) + res.Qc[:, -1].sum((-2, -1)))
        return em, bl

    em0, bl0 = run(CarbonIntensityPolicy(V=V))
    perfect = dict(discount=1.0, defer_weight=3.0)
    realistic = dict(discount=0.98, defer_weight=2.0)
    for name, pol, fc in [
        ("myopic (baseline)", None, None),
        ("lookahead H=1 (== myopic)",
         LookaheadDPPPolicy(V=V, H=1, **perfect),
         ClairvoyantTableForecaster(H=1)),
        ("lookahead H=8, perfect",
         LookaheadDPPPolicy(V=V, H=8, **perfect),
         ClairvoyantTableForecaster(H=8)),
        ("lookahead H=8, 20% noise",
         LookaheadDPPPolicy(V=V, H=8, **realistic),
         ClairvoyantTableForecaster(
             H=8, error=ForecastErrorModel(noise=0.2, seed=7))),
        ("lookahead H=8, seasonal-naive",
         LookaheadDPPPolicy(V=V, H=8, **realistic),
         SeasonalNaiveForecaster(H=8, period=48)),
    ]:
        em, bl = (em0, bl0) if pol is None else run(pol, fc)
        red = 100.0 * (1.0 - em / em0).mean()
        print(f"  {name:<30} reduction={red:6.1f}%   "
              f"backlog x{(bl / bl0).mean():.2f}")


def act3_oracle_sandwich(tab):
    print("\n== 3. clairvoyant-horizon oracle sandwich (single network) ==")
    spec = paper_spec()
    src = TableCarbonSource(table=tab)
    arrive = UniformArrivals(M=5, amax=240)
    key = jax.random.PRNGKey(1)
    la = LookaheadDPPPolicy(V=V, H=H, discount=1.0,
                            defer_weight=3.0)
    res = simulate(la, spec, src, arrive, T, key,
                   forecaster=ClairvoyantTableForecaster(H=H))
    actual = float(res.cum_emissions[-1])
    ee = np.asarray(res.energy_edge)
    ec = np.asarray(res.energy_cloud)
    for horizon, label in [(1, "H=1 (no deferral)"), (H, f"H={H}"),
                           (None, "full trace")]:
        lb = oracle_emissions_horizon(tab, ee, ec, horizon=horizon)
        print(f"  oracle {label:<18} lower bound = {lb:.3e}"
              f"   (policy emitted {actual / lb:.2f}x that)")


def main() -> None:
    tab = diurnal_table(T, 5, np.random.default_rng(0))
    act1_forecast_quality(tab)
    act2_lookahead_vs_myopic()
    act3_oracle_sandwich(tab)


if __name__ == "__main__":
    main()
