"""Carbon-aware WAN routing walkthrough (repro.network).

Runs the congested-uplink topology -- per cloud, a wide-but-dirty
default uplink and a clean-but-narrow alternate riding a green
backbone -- comparing a transfer-blind scheduler (the paper's policy
with a static route table) against the joint route+schedule DPP, and
prints where the savings come from (transfer vs compute energy) plus
the price paid in in-flight backlog.

    PYTHONPATH=src python examples/network_routing.py
"""
import os

import jax
import numpy as np

from repro.configs.fleet_scenarios import build_network_fleet
from repro.core import CarbonIntensityPolicy, simulate_fleet
from repro.network import NetworkAwareDPPPolicy, StaticRoutePolicy

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
PER_KIND = 2 if SMOKE else 16
T = 48 if SMOKE else 192
V = 0.1


def main() -> None:
    key = jax.random.PRNGKey(0)
    for kind in ("congested-uplink", "multi-region-uk-wan"):
        fleet = build_network_fleet([kind], per_kind=PER_KIND, Tc=96,
                                    seed=0)
        print(f"\n== {kind}: F={fleet.F} lanes x T={T} slots, "
              f"L={fleet.graph.dest.shape[-1]} routes, one compiled "
              f"call ==")

        def run(pol):
            res = jax.jit(lambda: simulate_fleet(pol, fleet, T, key))()
            return res

        blind = run(StaticRoutePolicy(CarbonIntensityPolicy(V=V)))
        aware = run(NetworkAwareDPPPolicy(V=V))
        em_b = np.asarray(blind.cum_emissions[:, -1])
        em_a = np.asarray(aware.cum_emissions[:, -1])
        red = 100.0 * (1.0 - em_a / em_b).mean()
        print(f"  transfer-blind  emissions {em_b.mean():.3e}  "
              f"(transfer kWh {float(blind.energy_transfer.sum(1).mean()):.0f})")
        print(f"  route-aware     emissions {em_a.mean():.3e}  "
              f"(transfer kWh {float(aware.energy_transfer.sum(1).mean()):.0f})")
        print(f"  emission reduction: {red:.1f}%   "
              f"throughput ratio: "
              f"{float(aware.processed.sum()) / float(blind.processed.sum()):.2f}   "
              f"in-flight backlog x"
              f"{(float(aware.Qt[:, -1].sum()) + 1) / (float(blind.Qt[:, -1].sum()) + 1):.1f}")


if __name__ == "__main__":
    main()
