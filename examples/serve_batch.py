"""Serve a small model with batched requests: prefill + KV-cache decode,
with carbon-aware admission (batches run eagerly when intensity is low,
are deferred -- up to an SLA bound -- when it is high: the paper's
"when" flexibility applied to inference).

    PYTHONPATH=src python examples/serve_batch.py

For the instrumented serving loop (donated-buffer compiled step,
decision-latency percentiles, live export) see repro.serve --
`python -m repro.serve` runs it on a synthetic workload.
"""
import os

import jax

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.carbon import UKRegionalTraceSource
from repro.launch.serve import greedy_generate
from repro.models import build_model

SLA_SLOTS = 3          # a batch may be deferred at most this many slots
CI_THRESHOLD = 220.0   # run immediately below this intensity (gCO2/kWh)


def main():
    cfg = registry.get_smoke_config("qwen1_5_0_5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    carbon = UKRegionalTraceSource(N=1)
    carbon_key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    queue = []   # (arrival_slot, prompts)
    emitted = 0.0
    served = 0
    energy_per_batch = 0.02  # kWh proxy for this tiny model

    for slot in range(4 if SMOKE else 16):
        # per-slot subkey, as the simulators thread it: a constant key
        # freezes every slot's draw for key-consuming sources (e.g.
        # RandomCarbonSource); the UK trace derives its own, but the
        # example should model the correct convention
        Ce, _ = carbon(jnp.asarray(slot),
                       jax.random.fold_in(carbon_key, slot))
        ci = float(Ce)
        # two new request batches arrive per slot
        for _ in range(2):
            queue.append((slot, rng.integers(
                0, cfg.vocab_size, (2, 16)).astype(np.int32)))

        run_now = []
        if ci < CI_THRESHOLD:
            run_now, queue = queue, []          # green power: drain
        else:
            keep = []
            for arr, p in queue:                # defer unless SLA-expired
                (run_now if slot - arr >= SLA_SLOTS else keep).append(
                    (arr, p))
            queue = keep

        for arr, prompts in run_now:
            toks = greedy_generate(model, params, jnp.asarray(prompts),
                                   gen_len=8, cache_len=32)
            served += 1
            emitted += ci * energy_per_batch
        print(f"slot {slot:2d} CI {ci:6.1f} ran {len(run_now):2d} "
              f"deferred {len(queue):2d} emitted {emitted:7.2f}")

    print(f"\nserved {served} batches, emissions {emitted:.2f} gCO2-eq")
    print("(an always-run policy would emit at the mean CI; deferral "
          "shifts work into the low-carbon slots)")


if __name__ == "__main__":
    main()
