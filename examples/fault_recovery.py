"""Scheduling through faults: a regional blackout hits a diurnal fleet
and three schedulers ride it out -- the queue-length baseline, the
paper's carbon policy fault-blind, and the same carbon policy wrapped
in StalenessGuardPolicy (outage-aware dispatch + staleness-decayed V).

    PYTHONPATH=src python examples/fault_recovery.py

Prints, per policy: emissions, completed-task fraction, and the
backlog-recovery profile (slots where the fault-induced excess backlog
tops two mean slots of arrivals). The guard should recover faster than the
unguarded carbon policy while staying far below queue-length
emissions. Swap SCENARIO to "telemetry-brownout" to watch the
staleness blending instead of the outage masking.
"""
import os
import time

import jax
import numpy as np

from repro.configs.fleet_scenarios import build_fleet, with_faults
from repro.core import CarbonIntensityPolicy, QueueLengthPolicy, simulate_fleet
from repro.faults import StalenessGuardPolicy, no_faults, stack_faults

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
PER_KIND = 2 if SMOKE else 16
T = 48 if SMOKE else 240
SCENARIO = "regional-blackout"


def main() -> None:
    fleet = build_fleet(["diurnal-slack"], per_kind=PER_KIND, Tc=96,
                        seed=0)
    faulted = with_faults(fleet, SCENARIO)
    N = fleet.spec.Pc.shape[1]
    zero = fleet._replace(
        faults=stack_faults([no_faults(N)] * fleet.F)
    )
    key = jax.random.PRNGKey(0)
    print(f"{SCENARIO}: {fleet.F} lanes x T={T} slots")

    carbon = CarbonIntensityPolicy(V=0.05)
    policies = [
        ("queue-length     ", QueueLengthPolicy()),
        ("carbon (unguarded)", carbon),
        ("guard(carbon)    ", StalenessGuardPolicy(inner=carbon)),
    ]
    for name, pol in policies:
        f = jax.jit(lambda flt, pol=pol: simulate_fleet(
            pol, flt, T, key, record="summary"
        ))
        f(faulted).cum_emissions.block_until_ready()  # compile
        t0 = time.perf_counter()
        r = f(faulted)
        r.cum_emissions.block_until_ready()
        dt = time.perf_counter() - t0
        r0 = f(zero)

        em = float(np.asarray(r.cum_emissions[:, -1]).mean())
        done = float(np.asarray(r.processed).sum()
                     - np.asarray(r.failed).sum())
        completed = 100.0 * done / float(np.asarray(r.arrived).sum())
        excess = np.asarray(r.backlog) - np.asarray(r0.backlog)
        theta = 2.0 * np.asarray(r.arrived).mean()
        recovery = float((excess > theta).sum(axis=-1).mean())
        print(
            f"  {name} emissions {em:12.3e}  completed {completed:5.1f}%"
            f"  slots-over-excess-threshold {recovery:6.1f}"
            f"  ({dt * 1e6 / (fleet.F * T):.1f} us/lane-slot)"
        )


if __name__ == "__main__":
    main()
