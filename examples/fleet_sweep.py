"""Fleet-scale scenario sweep: every registered scenario x 16 instances
(64 networks) simulated in ONE jitted call, carbon-aware policy vs the
queue-length baseline.

    PYTHONPATH=src python examples/fleet_sweep.py

Prints the per-scenario mean emission reduction and per-slot engine
latency. Swap score_backend="pallas" to route the score pass through
the fused Pallas kernel (identical actions; compiled on TPU, interpret
mode here).
"""
import os
import time

import jax
import numpy as np

from repro.configs.fleet_scenarios import SCENARIOS, build_fleet
from repro.core import CarbonIntensityPolicy, QueueLengthPolicy, simulate_fleet

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job
PER_KIND = 2 if SMOKE else 16
T = 30 if SMOKE else 300


def main() -> None:
    kinds = tuple(SCENARIOS)
    fleet = build_fleet(kinds, per_kind=PER_KIND, Tc=96, seed=0)
    key = jax.random.PRNGKey(0)
    print(f"fleet: {fleet.F} instances "
          f"({len(kinds)} scenarios x {PER_KIND}), T={T} slots")

    def run(policy):
        f = jax.jit(lambda k: simulate_fleet(policy, fleet, T, k))
        f(key).cum_emissions.block_until_ready()  # compile
        t0 = time.perf_counter()
        res = f(key)
        res.cum_emissions.block_until_ready()
        return res, time.perf_counter() - t0

    carb, dt = run(CarbonIntensityPolicy(V=0.05))
    base, _ = run(QueueLengthPolicy())
    print(f"engine: {dt * 1e6 / (fleet.F * T):.2f} us per instance-slot "
          f"({dt:.3f} s for the whole fleet)")

    final_c = np.asarray(carb.cum_emissions[:, -1])
    final_b = np.asarray(base.cum_emissions[:, -1])
    backlog = np.asarray(carb.Qe[:, -1].sum(-1)) + np.asarray(
        carb.Qc[:, -1].sum((-2, -1))
    )
    print(f"\n{'scenario':<22}{'reduction %':>12}{'final backlog':>16}")
    for i, kind in enumerate(kinds):
        sl = slice(i * PER_KIND, (i + 1) * PER_KIND)
        red = 100.0 * (1 - final_c[sl] / final_b[sl]).mean()
        print(f"{kind:<22}{red:>11.1f}%{backlog[sl].mean():>16.0f}")
    total = 100.0 * (1 - (final_c / final_b).mean())
    print(f"{'ALL':<22}{total:>11.1f}%{backlog.mean():>16.0f}")


if __name__ == "__main__":
    main()
