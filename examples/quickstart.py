"""Quickstart: reproduce the paper's headline result in ~30 seconds.

Simulates the paper's exact §V setup (M=5 AI-training task types from
Table I, N=5 clouds, Pe=4000 kWh, Pc=30000 kWh, a_m(t)~U{0..400}) under
(a) the queue-length baseline and (b) the carbon-intensity based policy
(Algorithm 1, V=0.05), for both carbon scenarios, and prints the
cumulative-emission reductions (paper: 63% random / 54% real-world).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import jax

SMOKE = os.environ.get("REPRO_SMOKE") == "1"  # CI examples-smoke job

from repro.configs.paper_workloads import V_PAPER, paper_spec
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UKRegionalTraceSource,
    UniformArrivals,
    simulate,
)


def main():
    spec = paper_spec()
    arrive = UniformArrivals(M=5, amax=400)
    key = jax.random.PRNGKey(0)
    T = 60 if SMOKE else 2000

    print(f"{'scenario':<12} {'policy':<22} {'cum. emissions':>16} "
          f"{'reduction':>10}")
    for name, carbon in [
        ("random", RandomCarbonSource(N=5)),
        ("real-world", UKRegionalTraceSource(N=5)),
    ]:
        base = None
        for pol_name, pol in [
            ("queue-length", QueueLengthPolicy()),
            (f"carbon (V={V_PAPER})", CarbonIntensityPolicy(V=V_PAPER)),
            ("carbon (V=0.20)", CarbonIntensityPolicy(V=0.20)),
        ]:
            r = jax.jit(
                lambda pol=pol, carbon=carbon: simulate(
                    pol, spec, carbon, arrive, T, key
                )
            )()
            cum = float(r.cum_emissions[-1])
            if base is None:
                base = cum
            red = 100.0 * (1 - cum / base)
            print(f"{name:<12} {pol_name:<22} {cum:16.3e} {red:9.1f}%")
        print()
    print("paper reports: 63% (random, V=0.05), 54% (real-world, V=0.05)")


if __name__ == "__main__":
    main()
