"""Perf-trend ledger: an append-only history of bench rows.

Every `benchmarks/run.py` invocation appends ONE line to
``artifacts/bench/history.jsonl`` -- timestamp, git sha (+ dirty flag),
jax/platform/seed provenance, and this run's fresh ``(name,
us_per_call, derived)`` rows. Unlike ``results.json`` (a snapshot that
merge-updates in place), the ledger only ever grows, so the perf
trajectory across PRs stays inspectable after the snapshot moves on;
CI uploads it as a build artifact next to results.json.

``python -m benchmarks.trend`` (or ``run.py --trend``) renders the
per-row deltas of the newest entry against the previous K entries:

    row                          us now     vs prev    vs window     n
    policy_fast/M2048xN256       1234.5       -2.1%        +0.4%     5

Also home to ``cost_columns``: the small normalizer that turns an XLA
``compiled.cost_analysis()`` (a dict on some backends, a singleton
list of dicts on others) plus a measured lower+compile wall time into
the flat ``{"compile_ms", "flops", "bytes_accessed"}`` dict benches
stamp onto their rows via ``paper_benches.EXTRAS``.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import time
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
HISTORY = ART / "history.jsonl"


def git_provenance(root: Path | None = None) -> dict:
    """{"git_sha": <12 hex or "unknown">, "git_dirty": bool} for the
    repo at `root`. Never raises: outside a checkout (or without a git
    binary) the sha is "unknown" and dirty is False -- bench rows are
    still writable, just unattributed."""
    root = Path(root) if root is not None else ART.parents[1]
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=root, capture_output=True, text=True, timeout=10,
        )
        status = subprocess.run(
            ["git", "status", "--porcelain"],
            cwd=root, capture_output=True, text=True, timeout=10,
        )
        if sha.returncode != 0:
            return {"git_sha": "unknown", "git_dirty": False}
        return {
            "git_sha": sha.stdout.strip(),
            "git_dirty": bool(status.stdout.strip())
            if status.returncode == 0 else False,
        }
    except (OSError, subprocess.SubprocessError):
        return {"git_sha": "unknown", "git_dirty": False}


def append_history(rows, env: dict, path: Path = HISTORY,
                   timestamp: float | None = None) -> dict:
    """Appends one ledger entry holding this run's fresh rows (name /
    us_per_call / derived only -- manifests and cost columns live in
    results.json). Returns the entry."""
    entry = {
        "ts": round(time.time() if timestamp is None else timestamp, 3),
        **env,
        "rows": [
            {"name": r["name"], "us_per_call": r["us_per_call"],
             "derived": r["derived"]}
            for r in rows
        ],
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as fh:
        fh.write(json.dumps(entry) + "\n")
    return entry


def load_history(path: Path = HISTORY) -> list:
    """All ledger entries, oldest first. Malformed lines are skipped
    (the ledger is append-only across PRs; one bad merge line must not
    brick the trend view)."""
    if not path.exists():
        return []
    entries = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            entry = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(entry, dict) and isinstance(entry.get("rows"), list):
            entries.append(entry)
    return entries


def render_trend(history: list, last: int = 5, only=()) -> str:
    """Markdown-ish delta table: newest entry's rows vs the previous
    `last` entries. "vs prev" is the % change against the most recent
    older entry carrying the row; "vs window" against the OLDEST entry
    in the window carrying it; n counts entries (window + newest) that
    have the row. `only` filters row names by substring."""
    if not history:
        return "# trend: ledger is empty (run benchmarks/run.py first)"
    newest = history[-1]
    window = history[max(0, len(history) - 1 - last):-1]
    head = (
        f"# trend: {newest.get('git_sha', '?')}"
        f"{'+dirty' if newest.get('git_dirty') else ''}"
        f" vs {len(window)} prior entr"
        f"{'y' if len(window) == 1 else 'ies'}"
        f" ({len(history)} in ledger)"
    )
    if not window:
        return head + "\n# (need >= 2 entries for deltas)"

    def series(name):
        return [
            r["us_per_call"]
            for e in window for r in e["rows"] if r["name"] == name
        ]

    lines = [
        head,
        f"{'row':<44} {'us now':>12} {'vs prev':>9} "
        f"{'vs window':>10} {'n':>3}",
    ]
    for row in newest["rows"]:
        name = row["name"]
        if only and not any(s in name for s in only):
            continue
        hist = series(name)
        now = row["us_per_call"]
        if not hist:
            prev_s = wind_s = "new"
            n = 1
        else:
            prev_s = f"{100.0 * (now / hist[-1] - 1.0):+.1f}%"
            wind_s = f"{100.0 * (now / hist[0] - 1.0):+.1f}%"
            n = len(hist) + 1
        lines.append(
            f"{name:<44} {now:>12.1f} {prev_s:>9} {wind_s:>10} {n:>3}"
        )
    return "\n".join(lines)


def cost_columns(fn, *args) -> dict:
    """Lower+compile `fn(*args)` and normalize XLA's cost analysis into
    flat row columns: compile_ms (measured lower->compile wall),
    flops, bytes_accessed (0.0 when the backend reports neither)."""
    import jax

    t0 = time.perf_counter()
    compiled = jax.jit(fn).lower(*args).compile()
    compile_ms = (time.perf_counter() - t0) * 1e3
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca = ca or {}
    return {
        "compile_ms": round(compile_ms, 3),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--last", type=int, default=5,
                    help="window of prior ledger entries to diff against")
    ap.add_argument("--only", action="append", default=[],
                    help="substring filter on row names (repeatable)")
    args = ap.parse_args()
    print(render_trend(load_history(), last=args.last, only=args.only))


if __name__ == "__main__":
    main()
