"""Benchmark harness: one function per paper table/figure + roofline.
Prints ``name,us_per_call,derived`` CSV and writes artifacts/bench/.

``--only SUBSTR`` (repeatable) selects benches whose function name
contains SUBSTR; a filtered run merges its rows into the existing
results.json instead of clobbering the full set. ``--smoke`` shrinks
bench instances to CI size (every code path compiles and runs; the
numbers are not representative) and prefixes row names with ``smoke/``
so a smoke run can never clobber committed full-size results.
``--compare`` diffs every fresh row's us_per_call against the committed
results.json BEFORE merging and exits nonzero when any row regresses by
more than ``--compare-tol`` (default 25%); rows faster than
``--compare-floor`` microseconds in the baseline are skipped as timer
noise. CI's bench-smoke job runs ``--smoke --compare`` against the
committed ``smoke/*`` baseline rows.

Benches that also run their workload with the telemetry taps on
(bench_fault_robustness, bench_telemetry_overhead) deposit a
``repro.telemetry.manifest`` dict per row in paper_benches.MANIFESTS;
it is stamped onto the matching results.json row under ``telemetry``.
Benches deposit further columns (serve latency percentiles, XLA
cost_analysis numbers) in paper_benches.EXTRAS, merged the same way.
Both are informational provenance: ``--compare`` gates us_per_call
ONLY, so a manifest-only diff (alert counts moving, peak backlog
shifting) never fails the gate.

Every invocation also appends ONE entry (git sha + dirty flag, env,
this run's fresh rows) to the append-only perf-trend ledger
``artifacts/bench/history.jsonl`` -- see benchmarks/trend.py. The
append happens even when ``--compare`` fails: the ledger records what
WAS measured; only the results.json baseline is protected from
regressed numbers. ``--trend`` renders the newest entry's per-row
deltas against the prior ledger entries after the run.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def main() -> None:
    from benchmarks import paper_benches
    from benchmarks.paper_benches import ALL_BENCHES

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=[])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--compare", action="store_true",
                    help="fail on >tol us_per_call regression vs the "
                         "committed results.json")
    ap.add_argument("--compare-tol", type=float, default=0.25)
    ap.add_argument("--compare-floor", type=float, default=100.0,
                    help="skip baseline rows faster than this many "
                         "microseconds (timer noise)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for bench instances; stamped into "
                         "every results.json row so any committed "
                         "number can be re-derived exactly")
    ap.add_argument("--trend", action="store_true",
                    help="after the run, render this entry's per-row "
                         "deltas against the perf-trend ledger "
                         "(artifacts/bench/history.jsonl)")
    ap.add_argument("--trend-last", type=int, default=5,
                    help="how many prior ledger entries --trend diffs "
                         "against")
    args = ap.parse_args()
    paper_benches.SMOKE = args.smoke
    paper_benches.SEED = args.seed
    benches = [
        b for b in ALL_BENCHES
        if not args.only or any(s in b.__name__ for s in args.only)
    ]

    # provenance stamped on every row so the perf trajectory in
    # results.json stays comparable across PRs / machines; git sha +
    # dirty flag tie each row to the code that produced it
    import jax

    from benchmarks import trend

    env = {
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "seed": args.seed,
        **trend.git_provenance(),
    }

    ART.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    all_rows = []
    for bench in benches:
        t0 = time.perf_counter()
        rows = bench()
        wall_s = time.perf_counter() - t0
        if args.smoke:
            rows = [(f"smoke/{n}", u, d) for n, u, d in rows]
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}")
        # bench_wall_s = total wall time of the bench FUNCTION that
        # produced the row (shared by its rows) -- compare like-named
        # benches across PRs, not rows within one bench. Telemetry
        # manifests are keyed by the unprefixed name (deposited before
        # the smoke/ prefix lands).
        for n, u, d in rows:
            bare = n[len("smoke/"):] if n.startswith("smoke/") else n
            row = {"name": n, "us_per_call": float(u),
                   "derived": float(d),
                   "bench_wall_s": round(wall_s, 3), **env}
            if bare in paper_benches.MANIFESTS:
                row["telemetry"] = paper_benches.MANIFESTS[bare]
            for k, v in paper_benches.EXTRAS.get(bare, {}).items():
                row.setdefault(k, v)
            all_rows.append(row)

    # roofline rows come from dry-run artifacts when present
    try:
        from benchmarks.roofline import bench_roofline

        t0 = time.perf_counter()
        roof = bench_roofline()
        wall_s = time.perf_counter() - t0
        for name, us, derived in roof:
            print(f"{name},{us:.1f},{derived:.4f}")
            all_rows.append(
                {"name": name, "us_per_call": us, "derived": derived,
                 "bench_wall_s": round(wall_s, 3), **env}
            )
    except Exception as e:  # dry-run not executed yet
        print(f"# roofline skipped: {e}", file=sys.stderr)

    out = ART / "results.json"
    committed = json.loads(out.read_text()) if out.exists() else []

    # ledger first, unconditionally: history.jsonl records what was
    # measured, including runs --compare is about to reject
    trend.append_history(all_rows, env)

    # --compare: diff fresh rows against the committed baseline BEFORE
    # merging, so the gate always sees the pre-run numbers.
    regressions = []
    if args.compare:
        base = {r["name"]: r["us_per_call"] for r in committed}
        for r in all_rows:
            old = base.get(r["name"])
            if old is None or old < args.compare_floor:
                continue
            if r["us_per_call"] > old * (1.0 + args.compare_tol):
                regressions.append((r["name"], old, r["us_per_call"]))
        for name, old, new in regressions:
            print(
                f"# REGRESSION {name}: {old:.1f} -> {new:.1f} us "
                f"(+{100.0 * (new / old - 1):.0f}% > "
                f"{100.0 * args.compare_tol:.0f}% tolerance)",
                file=sys.stderr,
            )

    # smoke rows are smoke/-prefixed (disjoint names), so a smoke run
    # must also merge -- never clobber committed full-size rows.
    if (args.only or args.smoke) and committed:
        kept = [
            r for r in committed
            if r["name"] not in {x["name"] for x in all_rows}
        ]
        all_rows = kept + all_rows
    if args.trend:
        print(trend.render_trend(trend.load_history(),
                                 last=args.trend_last, only=args.only))
    if regressions:
        # Leave results.json untouched: writing the regressed numbers
        # would install them as the next run's baseline and launder the
        # regression away on re-run.
        print(
            f"# results.json NOT updated ({len(regressions)} regression"
            f"{'s' if len(regressions) != 1 else ''})", file=sys.stderr,
        )
        sys.exit(1)
    out.write_text(json.dumps(all_rows, indent=2))


if __name__ == "__main__":
    main()
