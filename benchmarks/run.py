"""Benchmark harness: one function per paper table/figure + roofline.
Prints ``name,us_per_call,derived`` CSV and writes artifacts/bench/.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def main() -> None:
    from benchmarks.paper_benches import ALL_BENCHES

    ART.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    all_rows = []
    for bench in ALL_BENCHES:
        rows = bench()
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}")
        all_rows.extend(
            {"name": n, "us_per_call": float(u), "derived": float(d)}
            for n, u, d in rows
        )

    # roofline rows come from dry-run artifacts when present
    try:
        from benchmarks.roofline import bench_roofline

        for name, us, derived in bench_roofline():
            print(f"{name},{us:.1f},{derived:.4f}")
            all_rows.append(
                {"name": name, "us_per_call": us, "derived": derived}
            )
    except Exception as e:  # dry-run not executed yet
        print(f"# roofline skipped: {e}", file=sys.stderr)

    (ART / "results.json").write_text(json.dumps(all_rows, indent=2))


if __name__ == "__main__":
    main()
