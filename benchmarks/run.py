"""Benchmark harness: one function per paper table/figure + roofline.
Prints ``name,us_per_call,derived`` CSV and writes artifacts/bench/.

``--only SUBSTR`` (repeatable) selects benches whose function name
contains SUBSTR; a filtered run merges its rows into the existing
results.json instead of clobbering the full set. ``--smoke`` shrinks
bench instances to CI size (every code path compiles and runs; the
numbers are not representative) and prefixes row names with ``smoke/``
so a smoke run can never clobber committed full-size results.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def main() -> None:
    from benchmarks import paper_benches
    from benchmarks.paper_benches import ALL_BENCHES

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=[])
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    paper_benches.SMOKE = args.smoke
    benches = [
        b for b in ALL_BENCHES
        if not args.only or any(s in b.__name__ for s in args.only)
    ]

    # provenance stamped on every row so the perf trajectory in
    # results.json stays comparable across PRs / machines
    import jax

    env = {
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
    }

    ART.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    all_rows = []
    for bench in benches:
        t0 = time.perf_counter()
        rows = bench()
        wall_s = time.perf_counter() - t0
        if args.smoke:
            rows = [(f"smoke/{n}", u, d) for n, u, d in rows]
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}")
        # bench_wall_s = total wall time of the bench FUNCTION that
        # produced the row (shared by its rows) -- compare like-named
        # benches across PRs, not rows within one bench
        all_rows.extend(
            {"name": n, "us_per_call": float(u), "derived": float(d),
             "bench_wall_s": round(wall_s, 3), **env}
            for n, u, d in rows
        )

    # roofline rows come from dry-run artifacts when present
    try:
        from benchmarks.roofline import bench_roofline

        t0 = time.perf_counter()
        roof = bench_roofline()
        wall_s = time.perf_counter() - t0
        for name, us, derived in roof:
            print(f"{name},{us:.1f},{derived:.4f}")
            all_rows.append(
                {"name": name, "us_per_call": us, "derived": derived,
                 "bench_wall_s": round(wall_s, 3), **env}
            )
    except Exception as e:  # dry-run not executed yet
        print(f"# roofline skipped: {e}", file=sys.stderr)

    out = ART / "results.json"
    # smoke rows are smoke/-prefixed (disjoint names), so a smoke run
    # must also merge -- never clobber committed full-size rows.
    if (args.only or args.smoke) and out.exists():
        kept = [
            r for r in json.loads(out.read_text())
            if r["name"] not in {x["name"] for x in all_rows}
        ]
        all_rows = kept + all_rows
    out.write_text(json.dumps(all_rows, indent=2))


if __name__ == "__main__":
    main()
