"""Renders the README perf table from artifacts/bench/results.json.

    python -m benchmarks.perf_table

Prints a markdown table of the policy-step rows (per-slot latency of
the full default-config CarbonIntensityPolicy at large M/N) next to
the last numbers committed under the previous fill engine (PR 4), so
the before/after speedup stays visible after the rows are re-benched.
Paste the output into README.md when the numbers move.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = (
    Path(__file__).resolve().parents[1] / "artifacts" / "bench"
    / "results.json"
)

# us_per_call of the same workloads under the pre-unification (PR 4)
# engine -- the "before" column. Keys are the current policy_fast row
# names. NOTE the provenance: the policy_reference rows were benched
# with fast=True, i.e. the old argsort+cumsum path (whose while-tail
# degenerated to ~M sequential steps at these budgets); only the
# M2048xN256 number (old bench_policy_throughput default config) is the
# plain sequential lax.scan fill. Both old paths paid the ~250 ms
# batched argsort, which is why they land within ~2x of each other.
PR4_ENGINE_BASELINE_US = {
    "policy_fast/M1024xN128": 37817.5,   # policy_reference/M1024xN128 (fast=True)
    "policy_fast/M2048xN256": 276625.9,  # policy/M2048xN256 (sequential scan)
    "policy_fast/M4096xN256": 493383.7,  # policy_reference/M4096xN256 (fast=True)
}


def render(rows) -> str:
    by_name = {r["name"]: r for r in rows}
    lines = [
        "| policy step (default config) | PR 4 engine "
        "| chunked top_k fill | speedup |",
        "|---|---|---|---|",
    ]
    for name, before in PR4_ENGINE_BASELINE_US.items():
        row = by_name.get(name)
        if row is None:
            continue
        after = row["us_per_call"]
        lines.append(
            f"| {name.split('/')[1]} | {before / 1e3:.1f} ms "
            f"| {after / 1e3:.1f} ms | {before / after:.1f}x |"
        )
    summary = [
        r for r in rows if r["name"].startswith("fleet_summary/")
    ]
    if summary:
        lines.append("")
        lines.append(
            "| fleet, record=\"summary\" | us / lane-slot "
            "| full recording |"
        )
        lines.append("|---|---|---|")
        for r in sorted(summary, key=lambda r: r["name"]):
            full = (
                f"{r['derived']:.2f} us" if r["derived"] else "not run"
            )
            lines.append(
                f"| {r['name'].split('/')[1]} x T192 "
                f"| {r['us_per_call']:.2f} us | {full} |"
            )

    # fault-robustness rows (PR 7): one line per (scenario, policy)
    # joining the main row (us + recovery) with its /emissions and
    # /completed derived companions
    faults = sorted(
        r["name"][len("fault/"):]
        for r in rows
        if r["name"].startswith("fault/") and r["name"].count("/") == 2
    )
    if faults:
        lines.append("")
        lines.append(
            "| faulted fleet | us / lane-slot | recovery (slots) "
            "| emissions vs qlen | completed |"
        )
        lines.append("|---|---|---|---|---|")
        for stem in faults:
            main = by_name[f"fault/{stem}"]
            em = by_name.get(f"fault/{stem}/emissions")
            done = by_name.get(f"fault/{stem}/completed")
            em_s = "-" if em is None else f"-{em['derived']:.1f}%"
            done_s = "-" if done is None else f"{done['derived']:.1f}%"
            lines.append(
                f"| {stem} | {main['us_per_call']:.2f} us "
                f"| {main['derived']:.1f} | {em_s} | {done_s} |"
            )

    # telemetry taps overhead (observability layer): off vs on at the
    # same fleet size, plus the alert record the taps-on run produced
    tel_on = [
        r for r in rows
        if r["name"].startswith("telemetry/on/")
    ]
    if tel_on:
        lines.append("")
        lines.append(
            "| telemetry taps | off | on | overhead | alerts tripped |"
        )
        lines.append("|---|---|---|---|---|")
        for r in sorted(tel_on, key=lambda r: r["name"]):
            size = r["name"].split("/")[-1]
            off = by_name.get(f"telemetry/off/{size}")
            man = r.get("telemetry", {})
            n_mon = len(man.get("alerts", {}))
            tripped = sum(
                1 for a in man.get("alerts", {}).values()
                if a.get("tripped")
            )
            off_s = "-" if off is None else f"{off['us_per_call']:.2f} us"
            lines.append(
                f"| fleet {size} | {off_s} "
                f"| {r['us_per_call']:.2f} us "
                f"| {r['derived']:+.1f}% "
                f"| {tripped}/{n_mon} monitors |"
            )

    # live streaming (PR 9): taps-only vs flush-every-16 on the same
    # single-lane instance; derived on the flush16 row is the overhead
    # the committed <10% budget was asserted against
    stream = [
        r for r in rows if r["name"].startswith("stream/flush16/")
    ]
    if stream:
        lines.append("")
        lines.append(
            "| streaming taps | taps-only | flush every 16 | overhead |"
        )
        lines.append("|---|---|---|---|")
        for r in sorted(stream, key=lambda r: r["name"]):
            size = r["name"].split("/")[-1]
            off = by_name.get(f"stream/taps_only/{size}")
            off_s = "-" if off is None else f"{off['us_per_call']:.1f} us"
            lines.append(
                f"| {size} | {off_s} | {r['us_per_call']:.1f} us "
                f"| {r['derived']:+.1f}% |"
            )

    # deadline/SLO Pareto (PR 10): emission reduction vs misses vs
    # added waiting per deadline-aware policy on the generous-slack
    # fleet, plus the overload shedding rows
    slack = sorted(
        r["name"][len("deadline/slack/"):]
        for r in rows
        if r["name"].startswith("deadline/slack/")
        and r["name"].count("/") == 2
    )
    if slack:
        lines.append("")
        lines.append(
            "| deadline Pareto (generous slack) | us / lane-slot "
            "| emissions vs myopic | missed | added waiting |"
        )
        lines.append("|---|---|---|---|---|")
        for stem in slack:
            main = by_name[f"deadline/slack/{stem}"]
            miss = by_name.get(f"deadline/slack/{stem}/missed")
            wait = by_name.get(f"deadline/slack/{stem}/waiting")
            us = main["us_per_call"]
            us_s = "-" if us == 0.0 else f"{us:.2f} us"
            miss_s = "-" if miss is None else f"{miss['derived']:.2f}%"
            wait_s = "-" if wait is None else f"{wait['derived']:.0f}%"
            lines.append(
                f"| {stem} | {us_s} | {-main['derived']:+.1f}% "
                f"| {miss_s} | {wait_s} |"
            )
    over = [
        r for r in rows if r["name"].startswith("deadline/overload")
    ]
    if over:
        lines.append("")
        lines.append(
            "| overload shedding | us / lane-slot "
            "| % of offered load |"
        )
        lines.append("|---|---|---|")
        for r in sorted(over, key=lambda r: r["name"]):
            us = r["us_per_call"]
            lines.append(
                f"| {r['name'][len('deadline/'):]} "
                f"| {'-' if us == 0.0 else f'{us:.2f} us'} "
                f"| {r['derived']:.1f}% |"
            )

    # serving loop (PR 9): decision-latency percentiles + throughput
    # from the row's EXTRAS["latency"] columns
    serve = [r for r in rows if r["name"].startswith("serve/")]
    if serve:
        lines.append("")
        lines.append(
            "| serving loop | p50 | p95 | p99 | tasks/sec "
            "| max queue age |"
        )
        lines.append("|---|---|---|---|---|---|")
        for r in sorted(serve, key=lambda r: r["name"]):
            lat = r.get("latency", {})
            p95 = lat.get("p95_us")
            p99 = lat.get("p99_us")
            age = lat.get("max_queue_age")
            lines.append(
                f"| {r['name'].split('/', 1)[1]} "
                f"| {r['us_per_call']:.0f} us "
                f"| {'-' if p95 is None else f'{p95:.0f} us'} "
                f"| {'-' if p99 is None else f'{p99:.0f} us'} "
                f"| {r['derived']:,.0f} "
                f"| {'-' if age is None else f'{age} slots'} |"
            )
    return "\n".join(lines)


def main() -> None:
    print(render(json.loads(RESULTS.read_text())))


if __name__ == "__main__":
    main()
