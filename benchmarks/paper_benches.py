"""One benchmark per paper table/figure.

Each function returns a list of (name, us_per_call, derived) rows;
`derived` carries the quantity the paper plots (reduction %, queue
length, ...). run.py prints the combined CSV and writes
artifacts/bench/*.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_workloads import (
    TABLE_I, paper_spec,
)
from repro.core import (
    CarbonIntensityPolicy,
    QueueLengthPolicy,
    RandomCarbonSource,
    UKRegionalTraceSource,
    UniformArrivals,
    simulate,
    simulate_vsweep,
)

Row = Tuple[str, float, float]

# run.py --smoke flips this: benches shrink to CI-sized instances that
# exercise every code path (compile + execute) without the full sweep.
SMOKE = False

# run.py --seed sets this and stamps it on every results.json row, so
# any committed number can be re-derived exactly. Benches that draw
# instances read it at call time (run.py assigns before dispatch).
SEED = 0

# row name -> repro.telemetry.manifest(...) dict. Benches that also run
# their workload with the metrics taps on deposit the run's telemetry
# manifest here; run.py stamps it onto the matching results.json row
# (informational only -- --compare gates us_per_call and never fails
# on a manifest diff).
MANIFESTS: dict = {}

# row name -> dict of extra columns merged onto the matching
# results.json row (serve latency percentiles, XLA cost_analysis
# columns from trend.cost_columns, ...). Same contract as MANIFESTS:
# keyed by the bare (un-smoke-prefixed) name, informational only,
# never gated by --compare, and never allowed to shadow a core column.
EXTRAS: dict = {}


def _timeit(fn, n=5) -> float:
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def bench_table1() -> List[Row]:
    """Table I: energy consumption per AI-training task type (config echo
    + the derived per-task carbon at mean UK intensity ~200 gCO2/kWh)."""
    rows = []
    for name, pc, pe in TABLE_I:
        rows.append((f"table1/{name}", 0.0, pc * 200.0))
    return rows


def _paper_setup(carbon):
    spec = paper_spec()
    arrive = UniformArrivals(M=5, amax=400)
    key = jax.random.PRNGKey(0)
    T = 2000
    return spec, arrive, key, T, carbon


def bench_fig2_random() -> List[Row]:
    """Fig. 2: cumulative emissions, random carbon intensity.
    derived = % reduction vs queue-length policy (paper: 63% @ V=0.05)."""
    spec, arrive, key, T, carbon = _paper_setup(RandomCarbonSource(N=5))
    rows = []

    def run(policy):
        return simulate(policy, spec, carbon, arrive, T, key).cum_emissions

    base = None
    for name, pol in [
        ("queue-length", QueueLengthPolicy()),
        ("carbon V=0.01", CarbonIntensityPolicy(V=0.01)),
        ("carbon V=0.05", CarbonIntensityPolicy(V=0.05)),
        ("carbon V=0.20", CarbonIntensityPolicy(V=0.20)),
        ("carbon V=0.05 nofirstfit",
         CarbonIntensityPolicy(V=0.05, stop_at_first_unfit=False)),
    ]:
        f = jax.jit(lambda pol=pol: run(pol))
        us = _timeit(f, n=3)
        cum = float(f()[-1])
        if base is None:
            base = cum
        rows.append((f"fig2/{name}", us, 100.0 * (1 - cum / base)))
    return rows


def bench_fig3_realworld() -> List[Row]:
    """Fig. 3: cumulative emissions, UK-regional traces (paper: 54%)."""
    spec, arrive, key, T, carbon = _paper_setup(UKRegionalTraceSource(N=5))
    rows = []

    def run(policy):
        return simulate(policy, spec, carbon, arrive, T, key).cum_emissions

    base = None
    for name, pol in [
        ("queue-length", QueueLengthPolicy()),
        ("carbon V=0.05", CarbonIntensityPolicy(V=0.05)),
        ("carbon V=0.20", CarbonIntensityPolicy(V=0.20)),
    ]:
        f = jax.jit(lambda pol=pol: run(pol))
        us = _timeit(f, n=3)
        cum = float(f()[-1])
        if base is None:
            base = cum
        rows.append((f"fig3/{name}", us, 100.0 * (1 - cum / base)))
    return rows


def bench_fig4_queues() -> List[Row]:
    """Fig. 4: average edge-queue length (type m=1), random carbon.
    derived = mean Qe[0] over the horizon -- shows the V/delay tradeoff."""
    spec, arrive, key, T, carbon = _paper_setup(RandomCarbonSource(N=5))
    rows = []
    for name, pol in [
        ("queue-length", QueueLengthPolicy()),
        ("carbon V=0.01", CarbonIntensityPolicy(V=0.01)),
        ("carbon V=0.05", CarbonIntensityPolicy(V=0.05)),
        ("carbon V=0.20", CarbonIntensityPolicy(V=0.20)),
    ]:
        f = jax.jit(
            lambda pol=pol: simulate(pol, spec, carbon, arrive, T, key).Qe
        )
        us = _timeit(f, n=3)
        qe = np.asarray(f())
        rows.append((f"fig4/{name}", us, float(qe[:, 0].mean())))
    return rows


def bench_vsweep() -> List[Row]:
    """Beyond-paper: the whole Fig2+Fig4 tradeoff curve in ONE vmapped
    simulation (emissions reduction and delay vs V).

    Timing lives on the single `vsweep/total` row (us_per_call = one
    whole-sweep call, derived = sweep width); the per-V rows carry only
    the derived reduction % -- previously every per-V row repeated the
    amortized sweep time, which read as if each V cost that much."""
    spec, arrive, key, T, carbon = _paper_setup(RandomCarbonSource(N=5))
    Vs = jnp.asarray([0.005, 0.01, 0.02, 0.05, 0.1, 0.2])

    f = jax.jit(lambda: simulate_vsweep(
        lambda V: CarbonIntensityPolicy(V=V), Vs, spec, carbon, arrive, T,
        key,
    ).cum_emissions[:, -1])
    us = _timeit(f, n=2)
    base = float(jax.jit(lambda: simulate(
        QueueLengthPolicy(), spec, carbon, arrive, T, key
    ).cum_emissions[-1])())
    cums = np.asarray(f())
    rows: List[Row] = [("vsweep/total", us, float(len(cums)))]
    rows += [
        (f"vsweep/V={float(v):g}", 0.0, 100.0 * (1 - c / base))
        for v, c in zip(Vs, cums)
    ]
    return rows


def _random_instance(rng, M, N):
    from repro.core.queueing import NetworkSpec, NetworkState

    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=1e4,
        Pc=rng.uniform(1e3, 1e5, N).astype(np.float32),
    )
    state = NetworkState(
        Qe=jnp.asarray(rng.integers(0, 1000, M).astype(np.float32)),
        Qc=jnp.asarray(rng.integers(0, 1000, (M, N)).astype(np.float32)),
    )
    Ce = jnp.float32(300.0)
    Cc = jnp.asarray(rng.uniform(0, 700, N).astype(np.float32))
    return spec, state, Ce, Cc


def bench_policy_throughput() -> List[Row]:
    """Scheduler scalability: per-slot decision latency vs problem size
    (paper complexity claim: ~O(MN log MN))."""
    from repro.core.policies import CarbonIntensityPolicy

    rows = []
    rng = np.random.default_rng(0)
    pol = CarbonIntensityPolicy(V=0.05)
    for M, N in [(5, 5), (64, 16), (512, 64), (2048, 256)]:
        spec, state, Ce, Cc = _random_instance(rng, M, N)
        f = jax.jit(lambda s: pol(s, spec, Ce, Cc, None, None))
        us = _timeit(lambda: f(state), n=10)
        rows.append((f"policy/M{M}xN{N}", us, M * N))
    return rows


def bench_score_backends() -> List[Row]:
    """Reference-vs-Pallas per-slot latency: the full policy with each
    score backend, and the bare score pass, at fleet scale (M up to
    4096). On CPU the kernel runs in interpret mode -- the entries are
    the contract for the TPU numbers; derived = problem size M*N."""
    from repro.core.policies import CarbonIntensityPolicy
    from repro.kernels import ops

    rows = []
    rng = np.random.default_rng(0)
    for M, N in [(1024, 128), (4096, 256)]:
        spec, state, Ce, Cc = _random_instance(rng, M, N)
        for backend in ("reference", "pallas"):
            pol = CarbonIntensityPolicy(
                V=0.05, score_backend=backend
            )
            f = jax.jit(lambda s, pol=pol: pol(s, spec, Ce, Cc, None, None))
            us = _timeit(lambda: f(state), n=10)
            rows.append((f"policy_{backend}/M{M}xN{N}", us, M * N))

        # bare score pass (kernel contract vs jnp oracle)
        Qc, pc = state.Qc, jnp.asarray(spec.pc)
        Qe, pe = state.Qe, jnp.asarray(spec.pe)
        f_ref = jax.jit(lambda: ops.carbon_scores_ref(
            Qc, pc, Qe, pe, Cc, jnp.float32(15.0)
        ))
        rows.append((f"score_reference/M{M}xN{N}", _timeit(f_ref, 10),
                     M * N))
        # native fused kernel on TPU (interpret=None auto); off-TPU the
        # auto-dispatch would lower to the reference and measure
        # nothing, so force the emulated-kernel oracle there instead.
        interp = None if jax.default_backend() == "tpu" else True
        f_pal = jax.jit(lambda: ops.carbon_scores(
            Qc, pc, Qe, pe, Cc, jnp.float32(15.0), interpret=interp
        ))
        rows.append((f"score_pallas/M{M}xN{N}", _timeit(f_pal, 10), M * N))
    return rows


def _seq_policy_action(spec, state, Ce, Cc, V):
    """Sequential-fill oracle action (float32 numpy walk, the semantics
    the chunked greedy_fill replaced) -- the bench-level bit-parity
    anchor for the policy_fast rows."""
    from repro.kernels import ref

    pe, pc, Pe, Pc = spec.as_arrays()
    c, n1, b = ref.carbon_scores_ref(
        state.Qc, pc, state.Qe, pe,
        jnp.float32(V) * Cc, jnp.float32(V) * Ce,
    )
    c = np.asarray(c)
    b = np.asarray(b)
    n1 = np.asarray(n1)
    pe_n = np.asarray(pe)
    pc_n = np.asarray(pc)
    Qe = np.asarray(state.Qe)
    Qc = np.asarray(state.Qc)
    f32 = np.float32

    def walk(scores, e, caps, budget):
        order = np.argsort(scores / e, kind="stable")
        P = f32(budget)
        take = np.zeros_like(scores)
        for m in order:
            fits = f32(np.floor(P / e[m]))
            if fits <= 0:
                break  # default stop_at_first_unfit semantics
            if scores[m] < 0:
                t = f32(min(caps[m], fits))
                take[m] = t
                P = f32(P - f32(t * e[m]))
        return take

    M, N = pc_n.shape
    d = np.zeros((M, N), f32)
    d[np.arange(M), n1] = walk(b, pe_n, Qe, float(Pe))
    w = np.stack(
        [walk(c[:, n], pc_n[:, n], Qc[:, n], float(np.asarray(Pc)[n]))
         for n in range(N)],
        axis=1,
    )
    return d, w


def bench_policy_fast() -> List[Row]:
    """The tentpole row family: full default-config policy step at
    large M/N through the chunked top_k fill. Before timing, every
    instance asserts the actions are bit-identical to the sequential
    fill on the same inputs -- a wrong-but-fast fill can never post a
    number. derived = problem size M*N."""
    from repro.core.policies import CarbonIntensityPolicy

    sizes = [(256, 32)] if SMOKE else [
        (1024, 128), (2048, 128), (2048, 256), (4096, 256),
    ]
    rows = []
    rng = np.random.default_rng(0)
    pol = CarbonIntensityPolicy(V=0.05)
    for M, N in sizes:
        spec, state, Ce, Cc = _random_instance(rng, M, N)
        f = jax.jit(lambda s, pol=pol, spec=spec, Ce=Ce, Cc=Cc: pol(
            s, spec, Ce, Cc, None, None
        ))
        act = f(state)
        d_ref, w_ref = _seq_policy_action(spec, state, Ce, Cc, 0.05)
        np.testing.assert_array_equal(np.asarray(act.d), d_ref)
        np.testing.assert_array_equal(np.asarray(act.w), w_ref)
        us = _timeit(lambda: f(state), n=10)
        rows.append((f"policy_fast/M{M}xN{N}", us, M * N))
    return rows


def bench_fleet_summary() -> List[Row]:
    """Recording-mode rows: F diurnal lanes x T=192 slots in ONE
    compiled call with record="summary" (per-slot scalars + final state
    only -- the mode that unlocks F >= 512). us_per_call is per
    lane-slot; derived = the full-recording per-lane-slot time at the
    same F (0.0 where full recording is skipped). The F=256 instance
    asserts the summary scalar series is bitwise identical to full
    recording before timing."""
    from repro.configs.fleet_scenarios import build_fleet
    from repro.core import CarbonIntensityPolicy, simulate_fleet

    Fs = (8,) if SMOKE else (256, 512)
    T = 24 if SMOKE else 192
    key = jax.random.PRNGKey(0)
    pol = CarbonIntensityPolicy(V=0.05)
    rows = []
    for F in Fs:
        fleet = build_fleet(["diurnal"], per_kind=F, Tc=96, seed=0)

        def run(record, fleet=fleet):
            g = jax.jit(lambda k: simulate_fleet(
                pol, fleet, T, k, record=record
            ))
            res = g(key)  # compile + value
            jax.block_until_ready(res.cum_emissions)
            best = np.inf
            for _ in range(3):
                t0 = time.perf_counter()
                out = g(key)
                jax.block_until_ready(out.cum_emissions)
                best = min(best, time.perf_counter() - t0)
            return best * 1e6, res

        us_sum, r_sum = run("summary")
        full_us = 0.0
        if F == Fs[0]:
            full_us, r_full = run("full")
            np.testing.assert_array_equal(
                np.asarray(r_full.emissions), np.asarray(r_sum.emissions)
            )
            np.testing.assert_array_equal(
                np.asarray(r_full.Qe[:, -1]), np.asarray(r_sum.Qe[:, 0])
            )
            full_us = full_us / (F * T)
        assert r_sum.Qe.shape[1] == 1
        rows.append((f"fleet_summary/F{F}", us_sum / (F * T), full_us))
    return rows


def bench_fleet() -> List[Row]:
    """Fleet-scale scenario sweeps: >= 64 stacked region x workload-mix
    instances simulated in ONE jitted call. us_per_call is per
    instance-slot; derived = mean emission reduction (%) of the carbon
    policy vs the queue-length baseline across the fleet."""
    from repro.configs.fleet_scenarios import build_fleet
    from repro.core import (
        CarbonIntensityPolicy, QueueLengthPolicy, simulate_fleet,
    )

    rows = []
    key = jax.random.PRNGKey(0)
    for F_per, T in [(16, 200), (32, 100)]:  # F = 64, 128
        fleet = build_fleet(per_kind=F_per, Tc=96, seed=0)
        F = fleet.F

        def final(policy):
            return simulate_fleet(policy, fleet, T, key).cum_emissions[:, -1]

        f_carb = jax.jit(lambda: final(CarbonIntensityPolicy(V=0.05)))
        us = _timeit(f_carb, n=3)
        base = np.asarray(jax.jit(lambda: final(QueueLengthPolicy()))())
        carb = np.asarray(f_carb())
        reduction = float(100.0 * (1 - (carb / base).mean()))
        rows.append((f"fleet/F{F}xT{T}", us / (F * T), reduction))
    return rows


def bench_forecast_lookahead() -> List[Row]:
    """Lookahead-vs-myopic on the diurnal fleet scenarios (forecast
    subsystem). derived = mean cumulative-emission reduction (%) vs the
    myopic CarbonIntensityPolicy at the same V; us_per_call is per
    instance-slot. The `la_H1` rows are the receding-horizon policy at
    H=1, which is bit-identical to the myopic baseline by construction
    (0% reduction expected); H>=4 with perfect forecasts must land a
    real reduction -- that row is the acceptance gate for the forecast
    subsystem. `backlog` rows report the price of deferral: final
    backlog relative to myopic (derived = ratio in %)."""
    from repro.configs.fleet_scenarios import build_fleet
    from repro.core import (
        CarbonIntensityPolicy, LookaheadDPPPolicy, simulate_fleet,
    )
    from repro.forecast import (
        ClairvoyantTableForecaster, ForecastErrorModel,
        PersistenceForecaster, SeasonalNaiveForecaster,
    )

    V = 0.2
    per_kind, T = (2, 48) if SMOKE else (16, 192)
    horizons = (1, 4) if SMOKE else (1, 4, 8, 16)
    key = jax.random.PRNGKey(0)
    rows = []
    for kind in ("diurnal", "diurnal-slack"):
        fleet = build_fleet([kind], per_kind=per_kind, Tc=96, seed=0)
        F = fleet.F

        def run(policy, forecaster=None):
            f = jax.jit(lambda: simulate_fleet(
                policy, fleet, T, key, forecaster=forecaster
            ))
            f()  # compile
            t0 = time.perf_counter()
            res = f()
            jax.block_until_ready(res.cum_emissions)
            us = (time.perf_counter() - t0) * 1e6
            em = np.asarray(res.cum_emissions[:, -1])
            bl = np.asarray(
                res.Qe[:, -1].sum(-1) + res.Qc[:, -1].sum((-2, -1))
            )
            return us, em, bl

        _, em_base, bl_base = run(CarbonIntensityPolicy(V=V))

        def red(em):
            return float(100.0 * (1.0 - (em / em_base)).mean())

        configs = [
            (f"la_H{H}_perfect",
             LookaheadDPPPolicy(V=V, H=H, discount=1.0,
                                defer_weight=3.0),
             ClairvoyantTableForecaster(H=H))
            for H in horizons
        ]
        if not SMOKE:
            noisy = ForecastErrorModel(noise=0.2, seed=7)
            configs += [
                ("la_H8_noisy20",
                 LookaheadDPPPolicy(V=V, H=8, discount=0.98,
                                    defer_weight=2.0),
                 ClairvoyantTableForecaster(H=8, error=noisy)),
                ("la_H8_persistence",
                 LookaheadDPPPolicy(V=V, H=8, discount=0.98,
                                    defer_weight=2.0),
                 PersistenceForecaster(H=8)),
                ("la_H8_seasonal",
                 LookaheadDPPPolicy(V=V, H=8, discount=0.98,
                                    defer_weight=2.0),
                 SeasonalNaiveForecaster(H=8, period=48)),
            ]
        for name, pol, fc in configs:
            us, em, bl = run(pol, fc)
            rows.append((f"forecast/{kind}/{name}", us / (F * T), red(em)))
            rows.append((
                f"forecast/{kind}/{name}/backlog", 0.0,
                float(100.0 * (bl / bl_base).mean()),
            ))
    return rows


def bench_network_routing() -> List[Row]:
    """WAN transfer subsystem (repro.network). Three row families:

    * network/<topology>/... -- route-aware NetworkAwareDPPPolicy vs
      the transfer-blind StaticRoutePolicy(CarbonIntensityPolicy)
      baseline, 64+ lanes in one compiled call; derived = % cumulative-
      emission reduction vs blind (the congested-uplink reduction is
      the subsystem's acceptance gate). us_per_call is per lane-slot.
    * network/aware_pallas rows -- the same fleet with the route-score
      pass on the pallas backend (auto-dispatch: fused kernel on TPU,
      bit-identical reference off-TPU), the "no slower at fleet scale"
      contract row. NOTE: off-TPU both backends lower to identical
      code, so any ref-vs-pallas gap in a CPU run is timing noise; the
      row only becomes a real backend comparison on TPU.
    * network/route_kernel rows -- bare kernel-vs-reference contract at
      large single-call sizes; the interpret row is the CPU-emulated
      correctness oracle, expected slower, not a serving path.
    """
    from repro.configs.fleet_scenarios import build_network_fleet
    from repro.core import simulate_fleet
    from repro.kernels import ops
    from repro.network import NetworkAwareDPPPolicy, StaticRoutePolicy

    V = 0.1
    per_kind, T = (4, 24) if SMOKE else (64, 192)
    key = jax.random.PRNGKey(0)
    rows = []
    for kind in ("congested-uplink", "multi-region-uk-wan"):
        fleet = build_network_fleet([kind], per_kind=per_kind, Tc=96,
                                    seed=0)
        F = fleet.F

        def run(pol, fleet=fleet):
            # stride recording: only cum_emissions[:, -1] is read, so
            # recording every T//8-th slot cuts trajectory memory 8x
            # while keeping the final row bitwise identical (stride
            # rows land on slots k-1, ..., T-1; see _record_scan)
            f = jax.jit(lambda: simulate_fleet(pol, fleet, T, key,
                                               record=T // 8))
            f()  # compile
            best, em = np.inf, None
            for _ in range(3):
                t0 = time.perf_counter()
                res = f()
                jax.block_until_ready(res.cum_emissions)
                best = min(best, time.perf_counter() - t0)
                em = np.asarray(res.cum_emissions[:, -1])
            return best * 1e6, em

        us_b, em_b = run(
            StaticRoutePolicy(CarbonIntensityPolicy(V=V))
        )
        rows.append((f"network/{kind}/blind/F{F}xT{T}", us_b / (F * T),
                     0.0))
        for backend in ("reference", "pallas"):
            us, em = run(NetworkAwareDPPPolicy(
                V=V, score_backend=backend
            ))
            red = float(100.0 * (1.0 - (em / em_b)).mean())
            rows.append((
                f"network/{kind}/aware_{backend}/F{F}xT{T}",
                us / (F * T), red,
            ))
        if SMOKE:
            break

    # bare route-score kernel contract (single large call)
    sizes = [(256, 64)] if SMOKE else [(2048, 256), (4096, 512)]
    rng = np.random.default_rng(0)
    for M, L in sizes:
        Qt = jnp.asarray(rng.integers(0, 500, (M, L)).astype(np.float32))
        pt = jnp.asarray(rng.uniform(0, 5, (M, L)).astype(np.float32))
        Qcr = jnp.asarray(rng.integers(0, 900, (M, L)).astype(np.float32))
        extra = jnp.zeros((M, L), jnp.float32)
        Qe = jnp.asarray(rng.integers(0, 900, M).astype(np.float32))
        pe = jnp.asarray(rng.uniform(1, 8, M).astype(np.float32))
        VCt = jnp.asarray(rng.uniform(0, 40, L).astype(np.float32))
        V_Ce = jnp.float32(15.0)
        args = (Qt, pt, Qcr, extra, Qe, pe, VCt, V_Ce)
        f_ref = jax.jit(lambda: ops.route_scores_ref(*args))
        rows.append((f"network/route_kernel_ref/M{M}xL{L}",
                     _timeit(f_ref, 10), M * L))
        f_int = jax.jit(lambda: ops.route_scores(*args, interpret=True))
        rows.append((f"network/route_kernel_interpret/M{M}xL{L}",
                     _timeit(f_int, 3), M * L))
    return rows


def bench_fault_robustness() -> List[Row]:
    """Scheduling through faults (repro.faults). For every registered
    fault scenario, three policies run the same faulted fleet in one
    compiled call each:

      * queue-length      -- carbon-blind, throughput-optimal baseline;
      * carbon (unguarded)-- the paper's DPP policy, fault-blind;
      * guard(carbon)     -- StalenessGuardPolicy around the same DPP.

    Rows per (scenario, policy):
      fault/<scen>/<pol>            us_per_call per lane-slot,
                                    derived = backlog-recovery-time:
                                    mean slots per lane where the
                                    fault-induced EXCESS backlog (vs
                                    the same policy's zero-fault run)
                                    exceeds two mean slots of arrivals
                                    -- a ratio test would be blind to
                                    outage damage on top of the DPP
                                    policies' large V-induced steady
                                    backlog;
      fault/<scen>/<pol>/emissions  derived = % emission reduction vs
                                    queue-length on the SAME faults;
      fault/<scen>/<pol>/completed  derived = % of arrived tasks
                                    completed (processed - failed).

    Before any timing, the zero-fault fleet is asserted bitwise equal
    to the fault-free simulator (both score backends) -- the fault
    layer can never skew a committed number. Full-size runs also assert
    the acceptance ordering on the plain-fleet scenarios: the guard
    strictly beats unguarded carbon on recovery time and beats
    queue-length on emissions.
    """
    from repro.configs.fleet_scenarios import (
        build_fleet, build_network_fleet, with_faults,
    )
    from repro.core import simulate_fleet
    from repro.faults import StalenessGuardPolicy, no_faults, stack_faults
    from repro.network import NetworkAwareDPPPolicy

    V = 0.05
    per_kind, T = (4, 48) if SMOKE else (16, 192)
    key = jax.random.PRNGKey(SEED)
    rows: List[Row] = []

    fleet = build_fleet(["diurnal-slack"], per_kind=per_kind, Tc=96,
                        seed=SEED)
    wan = build_network_fleet(["congested-uplink"], per_kind=per_kind,
                              Tc=96, seed=SEED)

    def zero_faulted(flt):
        N = flt.spec.Pc.shape[1]
        L = None if flt.graph is None else flt.graph.bw.shape[-1]
        return flt._replace(
            faults=stack_faults([no_faults(N, L)] * flt.F)
        )

    # zero-fault bitwise anchor on both score backends, before timing
    for backend in ("reference", "pallas"):
        pol = StalenessGuardPolicy(
            inner=CarbonIntensityPolicy(V=V, score_backend=backend)
        )
        r0 = jax.jit(lambda p=pol: simulate_fleet(
            p.inner, fleet, T, key, record="summary"))()
        r1 = jax.jit(lambda p=pol: simulate_fleet(
            p, zero_faulted(fleet), T, key, record="summary"))()
        np.testing.assert_array_equal(
            np.asarray(r0.cum_emissions), np.asarray(r1.cum_emissions),
            err_msg=f"zero-fault parity broke ({backend})",
        )
        np.testing.assert_array_equal(
            np.asarray(r0.Qe[:, -1]), np.asarray(r1.Qe[:, -1]),
            err_msg=f"zero-fault parity broke ({backend})",
        )

    def run(pol, flt):
        f = jax.jit(lambda: simulate_fleet(
            pol, flt, T, key, record="summary"
        ))
        f()  # compile
        best, res = np.inf, None
        for _ in range(3):
            t0 = time.perf_counter()
            res = f()
            jax.block_until_ready(res.cum_emissions)
            best = min(best, time.perf_counter() - t0)
        return best * 1e6, res

    def measure(name, flt, policies, plain):
        from repro.telemetry import TelemetryConfig, manifest

        F = flt.F
        stats = {}
        for pname, pol in policies:
            faulted = with_faults(flt, name, seed=SEED)
            us, r = run(pol, faulted)
            # untimed taps-on rerun: deposits the run's telemetry
            # manifest (peak backlog, waste, alert record) for run.py
            # to stamp onto this row -- the timed runs stay taps-off so
            # the committed us_per_call numbers keep their baseline
            rt = jax.jit(lambda pol=pol, faulted=faulted: simulate_fleet(
                pol, faulted, T, key, record="summary",
                telemetry=TelemetryConfig(),
            ))()
            MANIFESTS[f"fault/{name}/{pname}"] = manifest(rt.telemetry)
            _, r0 = run(pol, zero_faulted(flt))
            excess = np.asarray(r.backlog) - np.asarray(r0.backlog)
            theta = 2.0 * np.asarray(r.arrived).mean()
            recovery = float((excess > theta).sum(axis=-1).mean())
            em = float(np.asarray(r.cum_emissions[:, -1]).mean())
            done = np.asarray(r.processed).sum() - np.asarray(
                r.failed).sum()
            completed = float(
                100.0 * done / max(np.asarray(r.arrived).sum(), 1.0)
            )
            stats[pname] = (us / (F * T), recovery, em, completed)
        em_qlen = stats["qlen"][2]
        for pname, (us, recovery, em, completed) in stats.items():
            rows.append((f"fault/{name}/{pname}", us, recovery))
            rows.append((f"fault/{name}/{pname}/emissions", 0.0,
                         100.0 * (1.0 - em / em_qlen)))
            rows.append((f"fault/{name}/{pname}/completed", 0.0,
                         completed))
        if not SMOKE and plain:
            # acceptance ordering: degradation-awareness must pay off
            assert stats["guard"][1] < stats["carbon"][1], (
                f"{name}: guard recovery {stats['guard'][1]:.1f} not "
                f"better than unguarded {stats['carbon'][1]:.1f}"
            )
            assert stats["guard"][2] < em_qlen, (
                f"{name}: guard emissions {stats['guard'][2]:.3g} not "
                f"below queue-length {em_qlen:.3g}"
            )
        return stats

    carbon = CarbonIntensityPolicy(V=V)
    plain_policies = [
        ("qlen", QueueLengthPolicy()),
        ("carbon", carbon),
        ("guard", StalenessGuardPolicy(inner=carbon)),
    ]
    for scen in ("regional-blackout", "telemetry-brownout"):
        measure(scen, fleet, plain_policies, plain=True)

    aware = NetworkAwareDPPPolicy(V=V)
    from repro.network import StaticRoutePolicy

    wan_policies = [
        ("qlen", StaticRoutePolicy(QueueLengthPolicy())),
        ("carbon", aware),
        ("guard", StalenessGuardPolicy(inner=aware)),
    ]
    measure("flappy-uplink", wan, wan_policies, plain=False)
    return rows


def bench_telemetry_overhead() -> List[Row]:
    """Price of observability: the same diurnal fleet with the metrics
    taps off vs on (full Telemetry frame: every per-slot series, the
    run gauges and all four SLO monitors), one compiled call each.

    Before any timing, every non-telemetry field of the taps-on result
    is asserted bitwise equal to the taps-off run -- the taps observe,
    never steer, and a perturbing tap can never post a number.
    us_per_call is per lane-slot; derived on the `on` row is the
    overhead in % vs taps-off. Full-size runs enforce the <5% overhead
    budget. The taps-on row's telemetry manifest is deposited in
    MANIFESTS for run.py to stamp into results.json.
    """
    from repro.configs.fleet_scenarios import build_fleet
    from repro.core import simulate_fleet
    from repro.telemetry import TelemetryConfig, manifest

    per_kind, T = (4, 48) if SMOKE else (32, 192)
    key = jax.random.PRNGKey(SEED)
    fleet = build_fleet(["diurnal-slack"], per_kind=per_kind, Tc=96,
                        seed=SEED)
    F = fleet.F
    pol = CarbonIntensityPolicy(V=0.05)

    def run(telemetry):
        f = jax.jit(lambda: simulate_fleet(
            pol, fleet, T, key, record="summary", telemetry=telemetry
        ))
        res = f()  # compile + value
        jax.block_until_ready(res.cum_emissions)
        best = np.inf
        for _ in range(5):
            t0 = time.perf_counter()
            out = f()
            jax.block_until_ready(out.cum_emissions)
            best = min(best, time.perf_counter() - t0)
        return best * 1e6, res

    us_off, r_off = run(None)
    us_on, r_on = run(TelemetryConfig())
    for field in type(r_off)._fields:
        if field == "telemetry":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(r_off, field)),
            np.asarray(getattr(r_on, field)),
            err_msg=f"taps perturbed the run: {field}",
        )
    overhead = 100.0 * (us_on / us_off - 1.0)
    if not SMOKE:
        assert overhead < 5.0, (
            f"telemetry taps cost {overhead:.1f}% per lane-slot "
            "(budget: 5%)"
        )
    MANIFESTS[f"telemetry/on/F{F}xT{T}"] = manifest(r_on.telemetry)
    return [
        (f"telemetry/off/F{F}xT{T}", us_off / (F * T), 0.0),
        (f"telemetry/on/F{F}xT{T}", us_on / (F * T), overhead),
    ]


# f32 run-gauge sums whose reduction XLA may reassociate between the
# single-scan and chunked-streaming programs (see bench_stream_overhead)
_REASSOC_GAUGES = frozenset({
    "total_emissions", "total_arrived", "total_processed",
    "total_failed", "total_wasted",
})


def bench_stream_overhead() -> List[Row]:
    """Price of LIVE observability: one simulate instance with the
    taps on (TelemetryConfig -- everything stays on device until the
    scan returns) vs streaming (StreamConfig(flush_every=16) -- the
    same taps, plus an io_callback flushing each 16-slot TapSeries
    slice to a host channel while the scan runs).

    Before any timing, the streaming run is asserted bitwise equal to
    the taps-only run -- every result field, every per-slot Telemetry
    series, every alert record (the f32 total_* roll-up gauges alone
    get 1 ulp of reassociation slack, see _REASSOC_GAUGES) -- and the
    channel-reassembled host series must equal the frame's bitwise:
    the flush is a pure observer on a proven-neutral chunked scan.
    us_per_call is per slot; derived on the streaming row is the
    overhead in %. Full-size runs enforce the <10% streaming budget
    (ISSUE 9 acceptance; the committed row carries the margin).
    Timed at ONE lane on purpose: callbacks scale with lanes, so
    per-lane cost is the honest unit -- fleet streaming pays F of
    these. The streaming row also gets trend.cost_columns
    (compile_ms / flops / bytes) via EXTRAS.

    Timing design: the two programs are timed PAIRED and INTERLEAVED
    (taps, stream, taps, stream, ...) and the overhead is the median
    of the per-pair ratios -- machine-wide drift hits both sides of a
    pair, so the median ratio isolates the callback cost where
    best-of-each (two independent minima) wobbles by +-10% on a busy
    host. us_per_call rows report the per-side medians.
    """
    from benchmarks.trend import cost_columns
    from repro.telemetry import (
        StreamConfig, TelemetryConfig, channel, reset_channel,
    )

    # full size picked so per-slot compute dominates the T/16 host
    # callbacks (at M=256 the callbacks alone are ~20% -- too small to
    # honestly claim the budget; the budget is a statement about
    # production-sized instances, not about callback latency)
    M, N, T = (32, 8, 64) if SMOKE else (2048, 64, 192)
    key = jax.random.PRNGKey(SEED)
    rng = np.random.default_rng(SEED)
    from repro.core import NetworkSpec

    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=1e4,
        Pc=rng.uniform(1e3, 1e5, N).astype(np.float32),
    )
    pol = CarbonIntensityPolicy(V=0.05)
    cs = UKRegionalTraceSource(N=N)
    ar = UniformArrivals(M=M, amax=300)
    tcfg = TelemetryConfig()
    scfg = StreamConfig(taps=tcfg, flush_every=16, channel="bench")

    def compiled(telemetry):
        f = jax.jit(lambda: simulate(
            pol, spec, cs, ar, T, key, record="summary",
            telemetry=telemetry,
        ))
        res = f()  # compile + value
        jax.block_until_ready(res.cum_emissions)
        return f, res

    def once(f):
        reset_channel("bench")
        t0 = time.perf_counter()
        jax.block_until_ready(f().cum_emissions)
        return time.perf_counter() - t0

    f_taps, r_taps = compiled(tcfg)
    f_stream, r_stream = compiled(scfg)
    pairs = [(once(f_taps), once(f_stream))
             for _ in range(3 if SMOKE else 9)]
    us_taps = float(np.median([a for a, _ in pairs])) * 1e6
    us_stream = float(np.median([b for _, b in pairs])) * 1e6
    overhead = 100.0 * (
        float(np.median([b / a for a, b in pairs])) - 1.0
    )

    # parity first, numbers second: a flush that steers is not a flush
    for field in type(r_taps)._fields:
        if field == "telemetry":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(r_taps, field)),
            np.asarray(getattr(r_stream, field)),
            err_msg=f"streaming perturbed the run: {field}",
        )
    for field in type(r_taps.telemetry)._fields:
        if field in _REASSOC_GAUGES:
            # total_* roll-ups are f32 sums over the [T] series; the
            # chunked streaming scan hands XLA a reshaped [T/k, k]
            # input and it may reassociate the reduction -- the SERIES
            # below are bitwise, the scalar sums get 1 ulp of slack
            np.testing.assert_allclose(
                np.asarray(getattr(r_taps.telemetry, field)),
                np.asarray(getattr(r_stream.telemetry, field)),
                rtol=1e-6,
                err_msg=f"streaming perturbed the taps: {field}",
            )
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(r_taps.telemetry, field)),
            np.asarray(getattr(r_stream.telemetry, field)),
            err_msg=f"streaming perturbed the taps: {field}",
        )
    # the channel holds exactly the LAST timed call's slices (reset
    # precedes every timed call), so the host view is one clean run
    host = channel("bench").series(0)
    for field in type(host)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(host, field)),
            np.asarray(getattr(r_taps.telemetry, field)),
            err_msg=f"host channel diverged from the frame: {field}",
        )
    reset_channel("bench")

    if not SMOKE:
        assert overhead < 10.0, (
            f"streaming costs {overhead:.1f}% per slot over taps-only "
            "(budget: 10%)"
        )
    stem = f"M{M}xN{N}xT{T}"
    EXTRAS[f"stream/flush16/{stem}"] = {
        "cost": cost_columns(lambda: simulate(
            pol, spec, cs, ar, T, key, record="summary", telemetry=tcfg,
        )),
    }
    return [
        (f"stream/taps_only/{stem}", us_taps / T, 0.0),
        (f"stream/flush16/{stem}", us_stream / T, overhead),
    ]


def bench_deadline_pareto() -> List[Row]:
    """Deadline/SLO layer (repro.deadlines): the emission-vs-miss-vs-
    waiting Pareto on the diurnal-slack fleet, plus graceful shedding
    under engineered overload. Row families:

      deadline/slack/<pol>           us_per_call per lane-slot,
                                     derived = % cumulative-emission
                                     reduction vs the myopic carbon
                                     policy (the bench_forecast
                                     baseline) on the generous-slack
                                     deadline fleet;
      deadline/slack/<pol>/missed    derived = deadline misses as % of
                                     admitted tasks;
      deadline/slack/<pol>/waiting   derived = added waiting: final
                                     backlog as % of the myopic
                                     baseline's (the price of
                                     deferral);
      deadline/overload/...          the overload arrival scenario with
                                     tight deadlines: the unshedded
                                     lane misses, the admission-control
                                     lane (shed-overload, 0.6 headroom)
                                     sheds instead; derived = misses
                                     (unshedded) / sheds (shed lane) as
                                     % of offered load;
      deadline/overload+blackout/... the same shed lane through a
                                     regional blackout under the
                                     staleness guard -- shed, don't
                                     diverge.

    Before any timing, the infinite-deadline anchor is asserted on both
    score backends: the slack policy on a no_deadlines() fleet is
    bitwise the plain LookaheadDPPPolicy run (a deadline layer that
    perturbs the unconstrained schedule can never post a number).
    Full-size runs assert the ISSUE acceptance: at least one
    deadline-aware policy reaches >= 90% of LookaheadDPP's emission
    reduction with ZERO misses on generous slack; shedding holds
    misses at 0 on the overload scenario where the unshedded baseline
    misses; and the overload+blackout lane sheds rather than letting
    backlog diverge.
    """
    from repro.configs.fleet_scenarios import (
        build_fleet, with_deadlines, with_faults,
    )
    from repro.core import LookaheadDPPPolicy, simulate_fleet
    from repro.deadlines import (
        EDDPolicy, SlackThresholdPolicy, WaitAwhilePolicy,
        no_deadlines, stack_deadlines,
    )
    from repro.faults import StalenessGuardPolicy
    from repro.forecast import ClairvoyantTableForecaster

    V = 0.2
    per_kind, T = (2, 24) if SMOKE else (16, 192)
    H = 4 if SMOKE else 16
    key = jax.random.PRNGKey(SEED)
    fleet = build_fleet(["diurnal-slack"], per_kind=per_kind, Tc=96,
                        seed=SEED)
    F = fleet.F
    fc = ClairvoyantTableForecaster(H=H)
    rows: List[Row] = []

    def inf_deadlines(flt):
        M = flt.arrival_amax.shape[1]
        return flt._replace(
            deadlines=stack_deadlines([no_deadlines(M)] * flt.F)
        )

    # infinite-deadline bitwise anchor on both backends, before timing
    for backend in ("reference", "pallas"):
        plain = jax.jit(lambda b=backend: simulate_fleet(
            LookaheadDPPPolicy(V=V, H=H, score_backend=b),
            fleet, T, key, forecaster=fc, record="summary"))()
        anchored = jax.jit(lambda b=backend: simulate_fleet(
            SlackThresholdPolicy(V=V, H=H, score_backend=b),
            inf_deadlines(fleet), T, key, forecaster=fc,
            record="summary"))()
        np.testing.assert_array_equal(
            np.asarray(plain.cum_emissions),
            np.asarray(anchored.cum_emissions),
            err_msg=f"infinite-deadline anchor broke ({backend})",
        )
        np.testing.assert_array_equal(
            np.asarray(plain.Qe[:, -1]), np.asarray(anchored.Qe[:, -1]),
            err_msg=f"infinite-deadline anchor broke ({backend})",
        )

    def run(pol, flt, forecaster=None):
        f = jax.jit(lambda: simulate_fleet(
            pol, flt, T, key, forecaster=forecaster, record="summary"
        ))
        f()  # compile
        best, res = np.inf, None
        for _ in range(3):
            t0 = time.perf_counter()
            res = f()
            jax.block_until_ready(res.cum_emissions)
            best = min(best, time.perf_counter() - t0)
        return best * 1e6, res

    def backlog(res):
        return np.asarray(
            res.Qe[:, -1].sum(-1) + res.Qc[:, -1].sum((-2, -1))
        )

    # -- generous-slack Pareto: emissions vs misses vs added waiting --
    _, r_base = run(CarbonIntensityPolicy(V=V), fleet)
    em_base = np.asarray(r_base.cum_emissions[:, -1])
    bl_base = backlog(r_base).mean()
    _, r_la = run(LookaheadDPPPolicy(V=V, H=H), fleet, forecaster=fc)
    red_la = float(
        100.0 * (1.0 - np.asarray(r_la.cum_emissions[:, -1]) / em_base
                 ).mean()
    )
    rows.append((f"deadline/slack/lookahead_H{H}", 0.0, red_la))

    slack = with_deadlines(fleet, "generous-slack", seed=SEED)
    stats = {}
    for name, pol, fcast in [
        ("slack_thresh", SlackThresholdPolicy(V=V, H=H), fc),
        ("waitawhile", WaitAwhilePolicy(V=V, H=H, J=2), fc),
        ("edd", EDDPolicy(), None),
    ]:
        us, r = run(pol, slack, forecaster=fcast)
        red = float(
            100.0 * (1.0 - np.asarray(r.cum_emissions[:, -1]) / em_base
                     ).mean()
        )
        missed = float(np.asarray(r.deadlines.missed).sum())
        admitted = float(np.asarray(r.deadlines.admitted).sum())
        miss_pct = 100.0 * missed / max(admitted, 1.0)
        wait_pct = float(100.0 * backlog(r).mean() / max(bl_base, 1.0))
        stats[name] = (red, missed)
        rows.append((f"deadline/slack/{name}", us / (F * T), red))
        rows.append((f"deadline/slack/{name}/missed", 0.0, miss_pct))
        rows.append((f"deadline/slack/{name}/waiting", 0.0, wait_pct))
    if not SMOKE:
        # acceptance: a deadline-aware policy matches >= 90% of the
        # unconstrained lookahead reduction at ZERO misses
        best = max(
            (red for red, missed in stats.values() if missed == 0.0),
            default=-np.inf,
        )
        assert best >= 0.9 * red_la, (
            f"no zero-miss deadline policy reached 90% of lookahead's "
            f"reduction ({best:.1f}% vs {red_la:.1f}%)"
        )

    # -- overload: shedding holds misses at 0 where the unshedded
    # baseline misses ------------------------------------------------
    over = build_fleet(["overload"], per_kind=per_kind, Tc=96, seed=SEED)
    Fo = over.F
    pol = SlackThresholdPolicy(V=V)
    us_u, r_u = run(pol, with_deadlines(over, "tight-uniform",
                                        seed=SEED))
    us_s, r_s = run(pol, with_deadlines(over, "shed-overload",
                                        seed=SEED))
    offered = float(
        np.asarray(r_u.deadlines.admitted).sum()
        + np.asarray(r_u.deadlines.shed).sum()
    )
    miss_u = float(np.asarray(r_u.deadlines.missed).sum())
    miss_s = float(np.asarray(r_s.deadlines.missed).sum())
    shed_s = float(np.asarray(r_s.deadlines.shed).sum())
    rows.append(("deadline/overload/unshedded", us_u / (Fo * T),
                 100.0 * miss_u / max(offered, 1.0)))
    rows.append(("deadline/overload/shed", us_s / (Fo * T),
                 100.0 * shed_s / max(offered, 1.0)))
    rows.append(("deadline/overload/shed/missed", 0.0,
                 100.0 * miss_s / max(offered, 1.0)))
    if not SMOKE:
        assert miss_u > 0.0, "overload scenario no longer induces misses"
        assert miss_s == 0.0, (
            f"admission control failed to hold misses at 0 under "
            f"overload ({miss_s:.0f} missed)"
        )

    # -- overload + blackout: shed, don't diverge --------------------
    guard = StalenessGuardPolicy(inner=SlackThresholdPolicy(V=V))
    blk = with_faults(over, "regional-blackout", seed=SEED)
    us_b, r_bu = run(guard, with_deadlines(blk, "tight-uniform",
                                           seed=SEED))
    us_bs, r_bs = run(guard, with_deadlines(blk, "shed-overload",
                                            seed=SEED))
    shed_b = float(np.asarray(r_bs.deadlines.shed).sum())
    bl_u = float(np.asarray(r_bu.backlog)[:, -1].mean())
    bl_s = float(np.asarray(r_bs.backlog)[:, -1].mean())
    rows.append(("deadline/overload+blackout/shed", us_bs / (Fo * T),
                 100.0 * shed_b / max(offered, 1.0)))
    rows.append(("deadline/overload+blackout/backlog_vs_unshedded",
                 0.0, 100.0 * bl_s / max(bl_u, 1.0)))
    if not SMOKE:
        assert shed_b > 0.0, "blackout overload lane shed nothing"
        assert bl_s < bl_u, (
            f"shedding did not bound the blackout backlog "
            f"({bl_s:.0f} vs {bl_u:.0f})"
        )
    return rows


def bench_serve_latency() -> List[Row]:
    """Serving-loop decision latency (repro.serve): the per-slot
    scheduling decision run as a host loop around one donated-buffer
    compiled step, >= 10^4 synthetic tasks through admission.

    us_per_call is the p50 decision latency over non-warmup slots;
    derived is throughput in tasks/sec. The full percentile set
    (p50/p95/p99/mean), max queue age and task count land on the row
    via EXTRAS["latency"], and the step function's cost_columns via
    EXTRAS["cost"] -- perf_table renders the serving table from them.
    """
    from benchmarks.trend import cost_columns
    from repro.core import NetworkSpec, init_state
    from repro.serve import make_serve_step, serve_loop

    M, N, amax, slots = (16, 4, 100, 24) if SMOKE else (64, 8, 300, 48)
    rng = np.random.default_rng(SEED)
    spec = NetworkSpec(
        pe=rng.uniform(1, 8, M).astype(np.float32),
        pc=rng.uniform(2, 100, (M, N)).astype(np.float32),
        Pe=1e4,
        Pc=rng.uniform(1e3, 1e5, N).astype(np.float32),
    )
    pol = CarbonIntensityPolicy(V=0.05)
    cs = UKRegionalTraceSource(N=N)
    ar = UniformArrivals(M=M, amax=amax)
    key = jax.random.PRNGKey(SEED)
    rep = serve_loop(pol, spec, cs, ar, slots, key, warmup=2)
    assert rep.tasks_arrived >= 1e4, (
        f"serve bench must cover >= 10^4 tasks, got "
        f"{rep.tasks_arrived:.0f}"
    )
    name = f"serve/M{M}xN{N}"
    EXTRAS[name] = {
        "latency": {
            "p50_us": rep.p50_us, "p95_us": rep.p95_us,
            "p99_us": rep.p99_us, "mean_us": rep.mean_us,
            "tasks_per_sec": rep.tasks_per_sec,
            "tasks": rep.tasks_arrived,
            "max_queue_age": rep.max_queue_age,
            "slots": rep.slots, "warmup": rep.warmup,
        },
        "cost": cost_columns(
            lambda s, t: make_serve_step(pol, spec, cs, ar, key)(s, t),
            init_state(M, N), jnp.int32(0),
        ),
    }
    return [(name, rep.p50_us, rep.tasks_per_sec)]


ALL_BENCHES = [
    bench_table1,
    bench_fig2_random,
    bench_fig3_realworld,
    bench_fig4_queues,
    bench_vsweep,
    bench_policy_throughput,
    bench_policy_fast,
    bench_score_backends,
    bench_fleet,
    bench_fleet_summary,
    bench_forecast_lookahead,
    bench_network_routing,
    bench_fault_robustness,
    bench_telemetry_overhead,
    bench_stream_overhead,
    bench_deadline_pareto,
    bench_serve_latency,
]
