"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_device / HBM_bw              [s]
  collective term = collective_bytes_per_device / link_bw      [s]
(the HLO module is already SPMD-partitioned, so cost_analysis numbers are
per-device; collective bytes are parsed from the compiled HLO with ring
weighting -- see launch/dryrun.parse_collective_bytes.)

Derived:
  MODEL_FLOPS  = useful math: 6*N_active*tokens (train),
                 2*N_active*tokens (prefill/decode), per device
  flop_ratio   = MODEL_FLOPS / HLO_FLOPS  (remat/redundancy waste)
  bound        = argmax of the three terms (the bottleneck)
  roofline_mfu = (MODEL_FLOPS/peak) / max(terms)  -- the MFU the compiled
                 program would reach if it exactly hit its dominant bound;
                 this is the roofline fraction reported in §Perf.

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import registry

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART = Path(__file__).resolve().parents[1] / "artifacts"


def _attention_flops_fwd(cfg, B: int, Sq: int, Skv: int,
                         causal: bool) -> float:
    """Useful attention math (2 einsums x 2 flops/MAC), causal-halved."""
    if not cfg.n_heads:
        return 0.0
    n_attn = (cfg.n_layers // cfg.attn_every if cfg.family == "hybrid"
              else cfg.n_layers)
    hd = cfg.resolved_head_dim
    frac = 0.5 if (causal and Sq == Skv) else 1.0
    return 4.0 * B * cfg.n_heads * Sq * Skv * hd * frac * n_attn


def _ssd_flops_fwd(cfg, B: int, S: int) -> float:
    """SSD useful math per forward: intra-chunk quadratic + state terms."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    n_ssm = (cfg.n_layers - cfg.n_layers // cfg.attn_every
             if cfg.family == "hybrid" else cfg.n_layers)
    H, P, N, Q = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    per_tok = 2 * N * Q / 2 + 2 * H * P * Q / 2  # scores + y_diag (causal)
    per_tok += 4 * H * P * N  # state outer-product + y_off
    return per_tok * B * S * n_ssm * 2  # x2 flops/MAC folded


def model_flops_per_device(rec) -> float:
    """Useful algorithmic FLOPs: 2*N_active per token (+attention/SSD
    terms), x3 for train (fwd+bwd). Approximate by design -- it is the
    numerator of the roofline MFU, not an exact replay of the HLO."""
    cfg = registry.get_config(rec["arch"])
    shp = registry.SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    n_act = cfg.active_params()
    B = shp["global_batch"]
    S = shp["seq_len"]
    if shp["kind"] == "train":
        tokens = S * B
        total = 6.0 * n_act * tokens
        total += 3.0 * (_attention_flops_fwd(cfg, B, S, S, True)
                        + _ssd_flops_fwd(cfg, B, S))
    elif shp["kind"] == "prefill":
        tokens = S * B
        total = 2.0 * n_act * tokens
        total += _attention_flops_fwd(cfg, B, S, S, True) + \
            _ssd_flops_fwd(cfg, B, S)
    else:  # decode: one new token attending to the full cache
        total = 2.0 * n_act * B
        total += _attention_flops_fwd(cfg, B, 1, S, False)
        if cfg.family in ("ssm", "hybrid"):
            total += _ssd_flops_fwd(cfg, B, 1)
    return total / n_dev


def analyze_record(rec) -> dict:
    if "cost_corrected" in rec:  # loop-trip-count corrected (see dryrun)
        flops = rec["cost_corrected"]["flops"]
        bytes_acc = rec["cost_corrected"]["bytes"]
        coll = rec["cost_corrected"]["collective_bytes"]
    else:
        flops = rec["cost"]["flops_per_device"]
        bytes_acc = rec["cost"]["bytes_accessed_per_device"]
        coll = rec["collectives"]["total_bytes"]
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bound = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    t_bound = max(terms.values())
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "seq_parallel": bool(rec.get("seq_parallel", False)),
        "calibrated": "cost_corrected" in rec,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bound": bound,
        "model_flops_per_device": mf,
        "hlo_flops_per_device": flops,
        "flop_ratio": mf / flops if flops else 0.0,
        "roofline_mfu": (mf / PEAK_FLOPS) / t_bound if t_bound else 0.0,
        "temp_bytes": rec["memory"].get("temp_size_in_bytes", 0),
        "arg_bytes": rec["memory"].get("argument_size_in_bytes", 0),
    }
    out["suggestion"] = suggest(out, rec)
    return out


def suggest(a, rec) -> str:
    if a["bound"] == "collective":
        big = max(rec["collectives"]["bytes"],
                  key=rec["collectives"]["bytes"].get)
        return (f"dominant collective is {big}: reshard to cut it "
                f"(FSDP gather grouping / EP a2a payload / hierarchical "
                f"pod reduction)")
    if a["bound"] == "memory":
        if a["flop_ratio"] < 0.5:
            return ("HLO does >2x useful FLOPs worth of traffic: check "
                    "remat policy and fp32 stacks in the saved residuals")
        return "fuse elementwise chains / shrink attention score dtype"
    if a["flop_ratio"] < 0.6:
        return ("compute-bound but <60% useful FLOPs: redundant recompute "
                "(remat) or padded shards dominate; revisit block remat "
                "policy / uneven-dim sharding")
    return "near compute roofline: tune block shapes (MXU alignment)"


def load_all():
    recs = []
    for p in sorted((ART / "dryrun").glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "ok":
            recs.append(rec)
    return recs


def run(write: bool = True):
    rows = [analyze_record(r) for r in load_all()]
    # Never write an EMPTY roofline.json: the dry-run hasn't been executed
    # yet, and the artifact's existence is what unskips the tier-1
    # consistency checks in tests/test_system.py.
    if write and rows:
        (ART / "roofline.json").write_text(json.dumps(rows, indent=2))
    return rows


def bench_roofline():
    """Bench-harness adapter: derived = roofline_mfu (%); us = dominant
    term in microseconds. Single-pod cells only (per the brief)."""
    rows = run()
    out = []
    for a in rows:
        if a["mesh"] != "single":
            continue
        t = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        sp = "__sp" if a.get("seq_parallel") else ""
        out.append((
            f"roofline/{a['arch']}__{a['shape']}{sp}",
            t * 1e6,
            100.0 * a["roofline_mfu"],
        ))
    return out


def markdown_table(rows=None, mesh="single") -> str:
    rows = rows or run(write=False)
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound "
        "| MODEL/HLO flops | roofline MFU |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in rows:
        if a["mesh"] != mesh:
            continue
        sp = " **+SP**" if a.get("seq_parallel") else ""
        lines.append(
            f"| {a['arch']}{sp} | {a['shape']} | {a['t_compute_s']*1e3:.2f} "
            f"| {a['t_memory_s']*1e3:.2f} | {a['t_collective_s']*1e3:.2f} "
            f"| **{a['bound']}** | {a['flop_ratio']:.2f} "
            f"| {a['roofline_mfu']*100:.1f}% |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
